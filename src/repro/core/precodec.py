"""Device-side pre-codecs applied to the state *before* serialization.

``int8`` — blockwise int8 quantization via the Pallas kernel
(:mod:`repro.kernels.quantize`): every float leaf is replaced by
``{"q": int8 blocks, "s": f32 scales}`` computed on-device, shrinking
flush volume ~4x (bf16: ~2x) at <1% relative error per block.  Lossy —
intended for high-frequency checkpoint tiers where the paper's concern
(PFS pressure) dominates, with periodic lossless checkpoints alongside.

Transform + inverse are structure-deterministic so saved and restoring
processes independently agree on the manifest leaf table.

Device-resident staging (:class:`DevicePrecodec`): instead of the
per-leaf ``quantize_tree`` tree_map + full-state ``device_get`` +
host-side dirty scan, the whole transformed state is assembled into one
uint32 word stream *on device* (one grouped quantize launch for every
float leaf together), the fused Pallas pass
(:mod:`repro.kernels.fused`) XORs it against the previous staged
snapshot and emits the per-chunk dirty mask + digests, and only the
dirty chunks are copied D2H — asynchronously, overlapped with the
caller's next train step.  ``save()`` then consumes the staged buffers
(see ``engine.CheckpointConfig.device_precodec``); the per-leaf host
path stays as the executable reference spec the staged stream is
asserted byte-identical against.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from time import perf_counter
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.serialize import Buffer, LeafEntry
from repro.kernels.fused.ops import (
    CHUNK_ALIGN,
    digests_from_meta,
    fused_precodec,
)
from repro.kernels.quantize import dequantize, quantize
from repro.kernels.quantize.ops import TILE, quantize_blocks_needed
from repro.utils.treelib import flatten_with_names

_FLOATS = {jnp.dtype(d) for d in (jnp.float32, jnp.float64, jnp.bfloat16, jnp.float16)}
# leaves smaller than one kernel tile stay raw: the (32, 128) tile pad
# would inflate them, and tiny tensors (norm scales, biases) are exactly
# where int8 noise hurts most.
MIN_QUANT_ELEMS = 4096


def _is_float_leaf(x: Any) -> bool:
    try:
        if jnp.dtype(getattr(x, "dtype", None)) not in _FLOATS:
            return False
    except TypeError:
        return False
    size = int(np.prod(np.shape(x))) if np.shape(x) else 1
    return size >= MIN_QUANT_ELEMS


def quantize_tree(state: Any) -> Any:
    def f(leaf):
        if not _is_float_leaf(leaf):
            return leaf
        q, s = quantize(jnp.asarray(leaf))
        return {"q": q, "s": s}

    return jax.tree_util.tree_map(f, state)


def quant_target_like(target: Any) -> Any:
    """The structure ``quantize_tree`` would produce, as ShapeDtypeStructs."""

    def f(leaf):
        if not _is_float_leaf(leaf):
            return leaf
        n = int(np.prod(np.shape(leaf))) if np.shape(leaf) else 1
        blocks = quantize_blocks_needed(n)
        return {
            "q": jax.ShapeDtypeStruct((blocks, 128), jnp.int8),
            "s": jax.ShapeDtypeStruct((blocks,), jnp.float32),
        }

    return jax.tree_util.tree_map(f, target)


def _dequantize_leaf_np(q: Any, s: Any, t: Any) -> np.ndarray:
    """Vectorized host-side dequant of one leaf: ``q * s`` per block.

    Bit-identical to the kernel/oracle result (both are a plain f32
    multiply per element), but a single NumPy expression instead of a
    jit dispatch + device round trip per leaf — the restore path is on
    the host anyway, where the D2H-side kernel buys nothing.
    """
    n = int(np.prod(np.shape(t))) if np.shape(t) else 1
    x = np.asarray(q, np.float32) * np.asarray(s, np.float32)[:, None]
    return (
        x.reshape(-1)[:n]
        .reshape(np.shape(t))
        .astype(np.dtype(getattr(t, "dtype", np.float32)))
    )


def dequantize_tree(qtree: Any, target: Any, *, pool: Any = None) -> Any:
    """Invert ``quantize_tree`` into ``target``'s shapes/dtypes.

    Vectorized per leaf (one blockwise ``q * s`` NumPy expression) and —
    given ``pool`` — parallel across leaves: the block multiplies and
    astype copies release the GIL, so a many-leaf train state
    dequantizes at memory bandwidth instead of crawling through a
    serial per-leaf jit loop.  The seed per-leaf kernel loop survives
    as :func:`dequantize_tree_reference`, the executable spec the
    vectorized path is tested bit-identical against.
    """
    tleaves, tdef = jax.tree_util.tree_flatten(target)
    qleaves = jax.tree_util.tree_leaves(
        qtree, is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
    )
    if len(tleaves) != len(qleaves):
        raise ValueError("quantized tree does not match target structure")

    def one(job):
        t, q = job
        if isinstance(q, dict):
            return _dequantize_leaf_np(q["q"], q["s"], t)
        return q

    jobs = list(zip(tleaves, qleaves))
    if pool is not None and len(jobs) > 1:
        out = list(pool.map(one, jobs))
    else:
        out = [one(j) for j in jobs]
    return jax.tree_util.tree_unflatten(tdef, out)


def dequantize_tree_reference(qtree: Any, target: Any) -> Any:
    """Seed restore path: per-leaf Pallas ``dequantize`` dispatches with
    a reshape/astype copy per leaf.  Kept as the executable spec for
    :func:`dequantize_tree`."""
    tleaves, tdef = jax.tree_util.tree_flatten(target)
    qleaves = jax.tree_util.tree_leaves(
        qtree, is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
    )
    if len(tleaves) != len(qleaves):
        raise ValueError("quantized tree does not match target structure")
    out = []
    for t, q in zip(tleaves, qleaves):
        if isinstance(q, dict):
            n = int(np.prod(np.shape(t))) if np.shape(t) else 1
            x = dequantize(jnp.asarray(q["q"]), jnp.asarray(q["s"]), n=n)
            out.append(np.asarray(x).reshape(np.shape(t)).astype(t.dtype))
        else:
            out.append(q)
    return jax.tree_util.tree_unflatten(tdef, out)


# -- device-resident pre-codec staging --------------------------------------


def _leaf_bytes_device(x: jax.Array) -> jax.Array:
    """Flat little-endian uint8 view of a device array — the on-device
    twin of ``np.asarray(leaf).tobytes()`` (C order)."""
    x = x.reshape(-1)
    if x.dtype == jnp.bool_:
        x = x.astype(jnp.uint8)
    return jax.lax.bitcast_convert_type(x, jnp.uint8).reshape(-1)


@dataclass
class _StreamSpec:
    """Per-(treedef, shapes, precodec) compiled device serializer."""

    fn: Any                    # jit: ordered leaf list -> uint32 word stream
    leaves: List[LeafEntry]    # transformed leaf table (manifest layout)
    total: int                 # serialized byte count


@dataclass
class _StageResult:
    base_step: Optional[int]   # base actually used (None: full snapshot)
    mask: np.ndarray           # (n_chunks,) bool dirty mask
    digests: np.ndarray        # (n_chunks,) uint64 raw-chunk digests
    dirty_idx: np.ndarray      # global indices of dirty chunks
    sub: jax.Array             # (n_dirty, chunk_words) u32, D2H in flight
    stage_s: float


@dataclass
class StagedPrecodec:
    """Handle for one in-flight staged step (returned by ``stage``)."""

    step: int
    base_step: Optional[int]   # the *requested* base (device may still miss)
    spec: _StreamSpec
    future: "Future[_StageResult]"


@dataclass
class StagedBuffers:
    """Host-side staging output, ready for ``encode_state_staged``."""

    stream: memoryview         # reconstructed raw logical stream
    leaves: List[LeafEntry]
    mask: np.ndarray           # (n_chunks,) bool
    deltas: Dict[int, np.ndarray]  # dirty global chunk -> u8 XOR payload
    digests: np.ndarray        # (n_chunks,) uint64
    base_step: Optional[int]
    stage_s: float             # device-side work (worker thread span)
    wait_s: float              # how long consume() blocked on the D2H


class DevicePrecodec:
    """Double-buffered device→host checkpoint staging.

    ``stage(step, state)`` runs on a single background worker: one
    device pass assembles the transformed state into a uint32 word
    stream (grouped quantize launch — one dispatch for *all* float
    leaves, not a per-leaf tree_map), the fused kernel diffs it against
    the device-held words of the previously staged step, and only the
    dirty chunks start an async D2H copy.  The caller's next train step
    runs concurrently; ``consume`` (called from ``save()``) blocks only
    on whatever D2H is still in flight, then reconstructs the raw
    stream host-side as ``base XOR delta`` over the dirty chunks.

    Buffer ownership: the worker owns the device word stream of the
    last staged step (the double buffer — it becomes the next step's
    base and is replaced, never mutated); the host never holds a full
    D2H copy of a delta step, only its dirty chunks plus the previous
    stream already resident in the engine's L0 twin.

    64-bit leaves require jax x64 mode: without it ``jnp.asarray``
    silently narrows and the staged stream would diverge from the host
    reference serializer, so the spec builder rejects them up front.
    """

    def __init__(
        self,
        *,
        chunk_size: int,
        precodec: str = "none",
        interpret: Optional[bool] = None,
    ):
        if chunk_size <= 0 or chunk_size % CHUNK_ALIGN:
            raise ValueError(
                f"device precodec requires chunk_size to be a positive "
                f"multiple of {CHUNK_ALIGN}, got {chunk_size}"
            )
        if precodec not in ("none", "int8"):
            raise ValueError(f"unknown precodec {precodec!r}")
        self.chunk_size = chunk_size
        self.precodec = precodec
        self.interpret = interpret
        self._specs: Dict[Any, _StreamSpec] = {}
        self._lock = threading.Lock()
        self._exec = ThreadPoolExecutor(1, thread_name_prefix="precodec-stage")
        self._base_words: Optional[jax.Array] = None
        self._base_step: Optional[int] = None

    # -- spec construction --------------------------------------------------

    def _spec_for(self, named, treedef) -> _StreamSpec:
        key = (
            treedef,
            tuple((tuple(np.shape(l)), str(np.asarray(l).dtype if not hasattr(l, "dtype") else l.dtype)) for _, l in named),
            self.precodec,
        )
        with self._lock:
            spec = self._specs.get(key)
        if spec is not None:
            return spec
        spec = self._build_spec(named, treedef)
        with self._lock:
            self._specs[key] = spec
        return spec

    def _build_spec(self, named, treedef) -> _StreamSpec:
        x64 = bool(jax.config.jax_enable_x64)
        quant_rows: List[Optional[Tuple[int, int]]] = []
        rows = 0
        for name, leaf in named:
            dt = np.dtype(getattr(leaf, "dtype", None) or np.asarray(leaf).dtype)
            if dt.itemsize == 8 and not x64:
                raise ValueError(
                    f"device_precodec: leaf {name!r} is {dt} but jax x64 "
                    "mode is off — the staged stream would silently narrow; "
                    "cast the leaf or enable jax_enable_x64"
                )
            if self.precodec == "int8" and _is_float_leaf(leaf):
                n = int(np.prod(np.shape(leaf))) if np.shape(leaf) else 1
                r = quantize_blocks_needed(n)
                quant_rows.append((rows, rows + r))
                rows += r
            else:
                quant_rows.append(None)

        def build(leaf_list):
            q = s = None
            qparts = []
            for leaf, qr in zip(leaf_list, quant_rows):
                if qr is not None:
                    flat = jnp.asarray(leaf).reshape(-1).astype(jnp.float32)
                    pad = (-flat.shape[0]) % TILE
                    if pad:
                        flat = jnp.pad(flat, (0, pad))
                    qparts.append(flat)
            if qparts:
                q, s = quantize(jnp.concatenate(qparts), interpret=self.interpret)
            parts = []
            for leaf, qr in zip(leaf_list, quant_rows):
                if qr is None:
                    parts.append(_leaf_bytes_device(jnp.asarray(leaf)))
                else:
                    a, b = qr
                    parts.append(_leaf_bytes_device(q[a:b]))
                    parts.append(_leaf_bytes_device(s[a:b]))
            u8 = jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.uint8)
            pad = (-u8.shape[0]) % 4
            if pad:
                u8 = jnp.pad(u8, (0, pad))
            return jax.lax.bitcast_convert_type(u8.reshape(-1, 4), jnp.uint32)

        # the transformed leaf table mirrors what the host reference path
        # (quantize_tree -> serialize_tree) would record in the manifest
        tree = jax.tree_util.tree_unflatten(treedef, [l for _, l in named])
        spec_tree = quant_target_like(tree) if self.precodec == "int8" else tree
        tnamed, _ = flatten_with_names(spec_tree)
        leaves: List[LeafEntry] = []
        off = 0
        for name, l in tnamed:
            dt = np.dtype(getattr(l, "dtype", None) or np.asarray(l).dtype)
            shape = tuple(getattr(l, "shape", np.shape(l)))
            size = int(np.prod(shape, dtype=np.int64) if shape else 1) * dt.itemsize
            leaves.append(
                LeafEntry(
                    name=name, dtype=str(dt), shape=shape, offset=off, size=size
                )
            )
            off += size
        return _StreamSpec(fn=jax.jit(build), leaves=leaves, total=off)

    # -- staging ------------------------------------------------------------

    def stage(
        self, step: int, state: Any, *, base_step: Optional[int] = None
    ) -> StagedPrecodec:
        """Kick the fused device pass for ``step`` on the worker thread.

        ``base_step`` is the engine's delta-base choice; the device only
        honors it when it still holds that step's words (otherwise the
        stage silently becomes a full snapshot and the returned buffers
        carry ``base_step=None``).  Returns immediately.
        """
        named, treedef = flatten_with_names(state)
        spec = self._spec_for(named, treedef)
        if spec.total == 0:
            raise ValueError("device precodec requires a non-empty state")
        leaf_list = [leaf for _, leaf in named]
        fut = self._exec.submit(self._run_stage, spec, leaf_list, step, base_step)
        return StagedPrecodec(step=step, base_step=base_step, spec=spec, future=fut)

    def _run_stage(
        self,
        spec: _StreamSpec,
        leaf_list: List[Any],
        step: int,
        base_step: Optional[int],
    ) -> _StageResult:
        t0 = perf_counter()
        words = spec.fn(leaf_list)
        use_base = (
            base_step is not None
            and self._base_step == base_step
            and self._base_words is not None
            and self._base_words.shape == words.shape
        )
        basew = self._base_words if use_base else jnp.zeros_like(words)
        delta, meta = fused_precodec(
            words, basew, chunk_words=self.chunk_size // 4,
            interpret=self.interpret,
        )
        meta_np = np.asarray(meta)
        digests = digests_from_meta(meta_np)
        n_chunks = len(digests)
        # no base: the XOR against zeros IS the stream; every chunk ships
        mask = meta_np[:, 0] > 0 if use_base else np.ones(n_chunks, bool)
        dirty_idx = np.flatnonzero(mask)
        sub = (
            delta
            if len(dirty_idx) == n_chunks
            else jnp.take(delta, jnp.asarray(dirty_idx), axis=0)
        )
        sub.copy_to_host_async()
        self._base_words, self._base_step = words, step
        return _StageResult(
            base_step=base_step if use_base else None,
            mask=mask, digests=digests, dirty_idx=dirty_idx, sub=sub,
            stage_s=perf_counter() - t0,
        )

    def consume(
        self, staged: StagedPrecodec, base_stream: Optional[Buffer] = None
    ) -> StagedBuffers:
        """Block on the staged D2H and reconstruct the raw stream.

        For delta stages ``base_stream`` must be the raw stream of the
        base step (the engine's L0 twin keeps it resident); the stream
        is rebuilt as a copy of the base with the dirty chunks XORed in
        place — no full-state D2H ever happens for a delta step.
        """
        t0 = perf_counter()
        res = staged.future.result()
        dirty_np = np.asarray(res.sub)
        wait_s = perf_counter() - t0
        total, cs = staged.spec.total, self.chunk_size
        deltas: Dict[int, np.ndarray] = {}
        if res.base_step is None:
            stream_arr = dirty_np.reshape(-1).view(np.uint8)[:total]
        else:
            if base_stream is None or len(base_stream) != total:
                raise ValueError(
                    "staged delta consume requires the base step's stream"
                )
            stream_arr = np.frombuffer(base_stream, np.uint8).copy()
            for i, gi in enumerate(res.dirty_idx):
                a = int(gi) * cs
                b = min(a + cs, total)
                db = dirty_np[i].view(np.uint8)[: b - a]
                np.bitwise_xor(stream_arr[a:b], db, out=stream_arr[a:b])
                deltas[int(gi)] = db
        return StagedBuffers(
            stream=memoryview(stream_arr).toreadonly(),
            leaves=staged.spec.leaves,
            mask=res.mask,
            deltas=deltas,
            digests=res.digests,
            base_step=res.base_step,
            stage_s=res.stage_s,
            wait_s=wait_s,
        )

    def invalidate_base(self) -> None:
        """Drop the device-held base words (forces the next stage full)."""
        self._base_words = self._base_step = None

    def close(self) -> None:
        self._exec.shutdown(wait=False)
