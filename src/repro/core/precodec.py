"""Device-side pre-codecs applied to the state *before* serialization.

``int8`` — blockwise int8 quantization via the Pallas kernel
(:mod:`repro.kernels.quantize`): every float leaf is replaced by
``{"q": int8 blocks, "s": f32 scales}`` computed on-device, shrinking
flush volume ~4x (bf16: ~2x) at <1% relative error per block.  Lossy —
intended for high-frequency checkpoint tiers where the paper's concern
(PFS pressure) dominates, with periodic lossless checkpoints alongside.

Transform + inverse are structure-deterministic so saved and restoring
processes independently agree on the manifest leaf table.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.quantize import dequantize, quantize
from repro.kernels.quantize.ops import TILE, quantize_blocks_needed

_FLOATS = {jnp.dtype(d) for d in (jnp.float32, jnp.float64, jnp.bfloat16, jnp.float16)}
# leaves smaller than one kernel tile stay raw: the (32, 128) tile pad
# would inflate them, and tiny tensors (norm scales, biases) are exactly
# where int8 noise hurts most.
MIN_QUANT_ELEMS = 4096


def _is_float_leaf(x: Any) -> bool:
    try:
        if jnp.dtype(getattr(x, "dtype", None)) not in _FLOATS:
            return False
    except TypeError:
        return False
    size = int(np.prod(np.shape(x))) if np.shape(x) else 1
    return size >= MIN_QUANT_ELEMS


def quantize_tree(state: Any) -> Any:
    def f(leaf):
        if not _is_float_leaf(leaf):
            return leaf
        q, s = quantize(jnp.asarray(leaf))
        return {"q": q, "s": s}

    return jax.tree_util.tree_map(f, state)


def quant_target_like(target: Any) -> Any:
    """The structure ``quantize_tree`` would produce, as ShapeDtypeStructs."""

    def f(leaf):
        if not _is_float_leaf(leaf):
            return leaf
        n = int(np.prod(np.shape(leaf))) if np.shape(leaf) else 1
        blocks = quantize_blocks_needed(n)
        return {
            "q": jax.ShapeDtypeStruct((blocks, 128), jnp.int8),
            "s": jax.ShapeDtypeStruct((blocks,), jnp.float32),
        }

    return jax.tree_util.tree_map(f, target)


def _dequantize_leaf_np(q: Any, s: Any, t: Any) -> np.ndarray:
    """Vectorized host-side dequant of one leaf: ``q * s`` per block.

    Bit-identical to the kernel/oracle result (both are a plain f32
    multiply per element), but a single NumPy expression instead of a
    jit dispatch + device round trip per leaf — the restore path is on
    the host anyway, where the D2H-side kernel buys nothing.
    """
    n = int(np.prod(np.shape(t))) if np.shape(t) else 1
    x = np.asarray(q, np.float32) * np.asarray(s, np.float32)[:, None]
    return (
        x.reshape(-1)[:n]
        .reshape(np.shape(t))
        .astype(np.dtype(getattr(t, "dtype", np.float32)))
    )


def dequantize_tree(qtree: Any, target: Any, *, pool: Any = None) -> Any:
    """Invert ``quantize_tree`` into ``target``'s shapes/dtypes.

    Vectorized per leaf (one blockwise ``q * s`` NumPy expression) and —
    given ``pool`` — parallel across leaves: the block multiplies and
    astype copies release the GIL, so a many-leaf train state
    dequantizes at memory bandwidth instead of crawling through a
    serial per-leaf jit loop.  The seed per-leaf kernel loop survives
    as :func:`dequantize_tree_reference`, the executable spec the
    vectorized path is tested bit-identical against.
    """
    tleaves, tdef = jax.tree_util.tree_flatten(target)
    qleaves = jax.tree_util.tree_leaves(
        qtree, is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
    )
    if len(tleaves) != len(qleaves):
        raise ValueError("quantized tree does not match target structure")

    def one(job):
        t, q = job
        if isinstance(q, dict):
            return _dequantize_leaf_np(q["q"], q["s"], t)
        return q

    jobs = list(zip(tleaves, qleaves))
    if pool is not None and len(jobs) > 1:
        out = list(pool.map(one, jobs))
    else:
        out = [one(j) for j in jobs]
    return jax.tree_util.tree_unflatten(tdef, out)


def dequantize_tree_reference(qtree: Any, target: Any) -> Any:
    """Seed restore path: per-leaf Pallas ``dequantize`` dispatches with
    a reshape/astype copy per leaf.  Kept as the executable spec for
    :func:`dequantize_tree`."""
    tleaves, tdef = jax.tree_util.tree_flatten(target)
    qleaves = jax.tree_util.tree_leaves(
        qtree, is_leaf=lambda x: isinstance(x, dict) and set(x) == {"q", "s"}
    )
    if len(tleaves) != len(qleaves):
        raise ValueError("quantized tree does not match target structure")
    out = []
    for t, q in zip(tleaves, qleaves):
        if isinstance(q, dict):
            n = int(np.prod(np.shape(t))) if np.shape(t) else 1
            x = dequantize(jnp.asarray(q["q"]), jnp.asarray(q["s"]), n=n)
            out.append(np.asarray(x).reshape(np.shape(t)).astype(t.dtype))
        else:
            out.append(q)
    return jax.tree_util.tree_unflatten(tdef, out)
