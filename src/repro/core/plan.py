"""Flush *and* read plans: the executable descriptions of one
asynchronous flush (write side) and one restore/reshard (read side).

Write side
==========

An aggregation *strategy* is a pure function
``(ClusterSpec, rank_sizes) -> FlushPlan``.  The plan lists every byte
movement needed to move N rank-local checkpoints into M remote files:

* ``SendItem`` — a gather hop: bytes of some rank's checkpoint shipped
  from the active backend holding them to a leader backend (network).
* ``WriteItem`` — a PFS write issued by one backend: (file, offset, size)
  sourced from some rank's checkpoint blob at ``src_offset``.

At paper scale (thousands of nodes x 32 ranks/node) a plan holds 10^5+
movements, so the canonical representation is *columnar*:
:class:`PlanArrays` stores parallel int64 NumPy columns per write/send
plus a file-name table, and every hot path (strategy builders,
:func:`validate_plan`, the simulator front-end) is an array program over
those columns.  The frozen ``WriteItem``/``SendItem`` dataclasses remain
the item-level view — ``plan.writes``/``plan.sends`` materialize them
lazily for the real executor and small-scale consumers, and
``PlanArrays.from_items`` converts back, losslessly.

Column semantics (all parallel int64 arrays; one row per movement):

:class:`WriteColumns`
    * ``backend``     — node id of the active backend issuing the write
    * ``file_id``     — index into ``PlanArrays.file_names``
    * ``file_offset`` — destination byte offset inside that file (>= 0)
    * ``size``        — bytes moved (> 0)
    * ``src_rank``    — whose stored checkpoint blob the bytes come from
    * ``src_offset``  — offset inside that rank's stored blob (>= 0)
    * ``round``       — barrier round (MPI-IO multi-phase); 0 = free-running

:class:`SendColumns`
    * ``src_backend`` — the source rank's home node (must hold the blob)
    * ``dst_backend`` — the leader/aggregator node receiving the bytes
    * ``src_rank`` / ``src_offset`` / ``size`` / ``round`` — as above

Invariants, enforced by :func:`validate_plan` (columnar) and its
executable spec :func:`validate_plan_reference` (item-loop):

1. *source coverage* — per rank, write ``src`` slices tile
   ``[0, stored_size)`` exactly (no gap, no overlap, no double write);
2. *destination disjointness* — per file, ``[file_offset, +size)``
   intervals never overlap and stay within the declared file size;
3. *send coverage* — every write issued by a backend other than the
   source rank's home node is fed by sends covering exactly those bytes,
   and every send originates at the source rank's home node;
4. *stripe disjointness* (when ``plan.stripe_disjoint``) — no PFS stripe
   has two distinct writers.

Read side
=========

The restore path inverts the write side.  :class:`FileLayout` is the
extent table of where every *stored-space* byte landed (stored space =
the concatenation of all rank blobs in rank order); it is derived either
from a ``FlushPlan`` (:meth:`FileLayout.from_flush_plan`) or from a
saved manifest's placement (``Manifest.file_layout()``).  A *consumer* —
a restore onto an arbitrary new geometry, or a partial (per-leaf)
restore for serving — states byte-range *requests* against stored space,
and :func:`build_read_plan` maps them onto file extents as an array
program (``np.searchsorted`` over the layout's ``start`` column — no
per-item Python loops), so planning a 100k-rank restore is milliseconds.

Chunk-framed checkpoints (see :mod:`repro.core.serialize`) need nothing
special here: a chunk's stored payload is an ordinary stored-space
interval (``rank stored offset + chunk stored_off``), so partial restore
under compression asks for exactly the chunks covering the requested
leaves — merged into minimal requests by :func:`merge_intervals` — and
the same planner/validator/executor machinery serves whole-blob and
chunk-granular reads alike.

:class:`ReadColumns` (parallel int64; one row per ranged ``pread``):
    * ``reader``      — consumer-side node issuing the read (work unit
      owner for the thread pool; the read twin of ``backend``)
    * ``file_id``     — index into ``ReadPlan.file_names``
    * ``file_offset`` — source byte offset inside that file (>= 0)
    * ``size``        — bytes read (> 0)
    * ``dst_req``     — index of the request this piece satisfies
    * ``dst_offset``  — destination offset inside that request's buffer

Invariants, enforced by :func:`validate_read_plan`:

1. *request coverage* — per request, ``dst`` slices tile
   ``[0, req_size)`` exactly (restore never invents or drops a byte);
2. *in-bounds reads* — every ``[file_offset, +size)`` stays inside the
   declared file size;
3. *layout consistency* (when the layout is supplied) — each read's file
   extent is exactly where the layout says the request's stored bytes
   live.

Executors (real files / discrete-event simulator) consume plans without
knowing which strategy produced them — this is the co-design seam the
paper argues for: strategy decides *who writes what where*, the executor
and its contention model price/perform it.  The read side keeps the same
seam: layout inversion decides *who reads what from where*, and
``RealExecutor.execute_read_plan`` performs it.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.prefix_sum import LeaderAssignment, ScanMeta


@dataclass(frozen=True)
class WriteItem:
    backend: int        # node id of the active backend issuing the write
    file: str           # logical remote file name
    file_offset: int
    size: int
    src_rank: int       # whose checkpoint blob this slice comes from
    src_offset: int     # offset inside that rank's blob
    round: int = 0      # barrier round (MPI-IO multi-phase); 0 = unsynchronized

    def __post_init__(self):
        if self.size <= 0:
            raise ValueError("WriteItem.size must be positive")
        if self.file_offset < 0 or self.src_offset < 0:
            raise ValueError("offsets must be non-negative")


@dataclass(frozen=True)
class SendItem:
    src_backend: int
    dst_backend: int
    src_rank: int
    src_offset: int
    size: int
    round: int = 0

    def __post_init__(self):
        if self.size <= 0:
            raise ValueError("SendItem.size must be positive")


# ---------------------------------------------------------------------------
# Columnar (structure-of-arrays) plan core
# ---------------------------------------------------------------------------


def _i64(x) -> np.ndarray:
    return np.asarray(x, dtype=np.int64)


_W_COLS = ("backend", "file_id", "file_offset", "size", "src_rank", "src_offset", "round")
_S_COLS = ("src_backend", "dst_backend", "src_rank", "src_offset", "size", "round")


@dataclass
class WriteColumns:
    """Parallel int64 columns, one row per :class:`WriteItem`."""

    backend: np.ndarray
    file_id: np.ndarray
    file_offset: np.ndarray
    size: np.ndarray
    src_rank: np.ndarray
    src_offset: np.ndarray
    round: np.ndarray

    def __post_init__(self):
        for name in _W_COLS:
            setattr(self, name, _i64(getattr(self, name)))
        if len({getattr(self, c).shape for c in _W_COLS}) != 1:
            raise ValueError("WriteColumns columns must have identical length")

    def __len__(self) -> int:
        return len(self.backend)

    @staticmethod
    def empty() -> "WriteColumns":
        z = np.empty(0, np.int64)
        return WriteColumns(z, z, z, z, z, z, z)

    def take(self, idx: np.ndarray) -> "WriteColumns":
        return WriteColumns(*(getattr(self, c)[idx] for c in _W_COLS))

    def with_round(self, rnd: int) -> "WriteColumns":
        cols = {c: getattr(self, c) for c in _W_COLS}
        cols["round"] = np.full(len(self), int(rnd), np.int64)
        return WriteColumns(**cols)

    @staticmethod
    def concat(parts: Sequence["WriteColumns"]) -> "WriteColumns":
        parts = [p for p in parts if len(p)]
        if not parts:
            return WriteColumns.empty()
        return WriteColumns(
            *(np.concatenate([getattr(p, c) for p in parts]) for c in _W_COLS)
        )


@dataclass
class SendColumns:
    """Parallel int64 columns, one row per :class:`SendItem`."""

    src_backend: np.ndarray
    dst_backend: np.ndarray
    src_rank: np.ndarray
    src_offset: np.ndarray
    size: np.ndarray
    round: np.ndarray

    def __post_init__(self):
        for name in _S_COLS:
            setattr(self, name, _i64(getattr(self, name)))
        if len({getattr(self, c).shape for c in _S_COLS}) != 1:
            raise ValueError("SendColumns columns must have identical length")

    def __len__(self) -> int:
        return len(self.src_backend)

    @staticmethod
    def empty() -> "SendColumns":
        z = np.empty(0, np.int64)
        return SendColumns(z, z, z, z, z, z)

    def take(self, idx: np.ndarray) -> "SendColumns":
        return SendColumns(*(getattr(self, c)[idx] for c in _S_COLS))

    def with_round(self, rnd: int) -> "SendColumns":
        cols = {c: getattr(self, c) for c in _S_COLS}
        cols["round"] = np.full(len(self), int(rnd), np.int64)
        return SendColumns(**cols)

    @staticmethod
    def concat(parts: Sequence["SendColumns"]) -> "SendColumns":
        parts = [p for p in parts if len(p)]
        if not parts:
            return SendColumns.empty()
        return SendColumns(
            *(np.concatenate([getattr(p, c) for p in parts]) for c in _S_COLS)
        )


def coalesce_write_columns(w: WriteColumns) -> WriteColumns:
    """Merge contiguous runs with identical (round, backend, file, rank).

    The columnar twin of the planner's item-level coalescing: one
    ``np.lexsort`` plus a boundary-difference pass.  Two sorted rows merge
    when both the destination and source offsets are contiguous.
    """
    if len(w) <= 1:
        return w
    order = np.lexsort((w.file_offset, w.src_rank, w.file_id, w.backend, w.round))
    b = w.take(order)
    same = (
        (b.round[1:] == b.round[:-1])
        & (b.backend[1:] == b.backend[:-1])
        & (b.file_id[1:] == b.file_id[:-1])
        & (b.src_rank[1:] == b.src_rank[:-1])
        & (b.file_offset[1:] == b.file_offset[:-1] + b.size[:-1])
        & (b.src_offset[1:] == b.src_offset[:-1] + b.size[:-1])
    )
    starts = np.flatnonzero(np.concatenate(([True], ~same)))
    return WriteColumns(
        backend=b.backend[starts],
        file_id=b.file_id[starts],
        file_offset=b.file_offset[starts],
        size=np.add.reduceat(b.size, starts),
        src_rank=b.src_rank[starts],
        src_offset=b.src_offset[starts],
        round=b.round[starts],
    )


def coalesce_send_columns(s: SendColumns) -> SendColumns:
    if len(s) <= 1:
        return s
    order = np.lexsort((s.src_offset, s.src_rank, s.dst_backend, s.src_backend, s.round))
    b = s.take(order)
    same = (
        (b.round[1:] == b.round[:-1])
        & (b.src_backend[1:] == b.src_backend[:-1])
        & (b.dst_backend[1:] == b.dst_backend[:-1])
        & (b.src_rank[1:] == b.src_rank[:-1])
        & (b.src_offset[1:] == b.src_offset[:-1] + b.size[:-1])
    )
    starts = np.flatnonzero(np.concatenate(([True], ~same)))
    return SendColumns(
        src_backend=b.src_backend[starts],
        dst_backend=b.dst_backend[starts],
        src_rank=b.src_rank[starts],
        src_offset=b.src_offset[starts],
        size=np.add.reduceat(b.size, starts),
        round=b.round[starts],
    )


@dataclass
class PlanArrays:
    """Columnar plan: write/send columns + the file-name table.

    ``file_names[file_id]`` resolves a write's ``file_id`` column to its
    logical file name; conversion to/from ``WriteItem``/``SendItem``
    lists is lossless (:meth:`from_items` / :meth:`to_write_items`).
    """

    file_names: List[str]
    writes: WriteColumns
    sends: SendColumns

    @property
    def n_writes(self) -> int:
        return len(self.writes)

    @property
    def n_sends(self) -> int:
        return len(self.sends)

    @staticmethod
    def from_items(
        writes: Sequence[WriteItem],
        sends: Sequence[SendItem] = (),
        file_names: Optional[Sequence[str]] = None,
    ) -> "PlanArrays":
        names: List[str] = list(file_names) if file_names is not None else []
        fid: Dict[str, int] = {nm: i for i, nm in enumerate(names)}
        w_file = np.empty(len(writes), np.int64)
        for i, w in enumerate(writes):
            j = fid.get(w.file)
            if j is None:
                j = fid[w.file] = len(names)
                names.append(w.file)
            w_file[i] = j
        wc = WriteColumns(
            backend=[w.backend for w in writes],
            file_id=w_file,
            file_offset=[w.file_offset for w in writes],
            size=[w.size for w in writes],
            src_rank=[w.src_rank for w in writes],
            src_offset=[w.src_offset for w in writes],
            round=[w.round for w in writes],
        )
        sc = SendColumns(
            src_backend=[s.src_backend for s in sends],
            dst_backend=[s.dst_backend for s in sends],
            src_rank=[s.src_rank for s in sends],
            src_offset=[s.src_offset for s in sends],
            size=[s.size for s in sends],
            round=[s.round for s in sends],
        )
        return PlanArrays(file_names=names, writes=wc, sends=sc)

    def to_write_items(self) -> List[WriteItem]:
        w = self.writes
        names = self.file_names
        return [
            WriteItem(backend=b, file=names[f], file_offset=fo, size=sz,
                      src_rank=sr, src_offset=so, round=rd)
            for b, f, fo, sz, sr, so, rd in zip(
                w.backend.tolist(), w.file_id.tolist(), w.file_offset.tolist(),
                w.size.tolist(), w.src_rank.tolist(), w.src_offset.tolist(),
                w.round.tolist(),
            )
        ]

    def to_send_items(self) -> List[SendItem]:
        s = self.sends
        return [
            SendItem(src_backend=sb, dst_backend=db, src_rank=sr,
                     src_offset=so, size=sz, round=rd)
            for sb, db, sr, so, sz, rd in zip(
                s.src_backend.tolist(), s.dst_backend.tolist(),
                s.src_rank.tolist(), s.src_offset.tolist(),
                s.size.tolist(), s.round.tolist(),
            )
        ]


class FlushPlan:
    """One flush, in columnar and/or item form.

    Strategy builders construct plans columnar (``arrays=...``);
    ``plan.writes`` / ``plan.sends`` materialize the item lists lazily on
    first access, so the real executor and small-scale consumers keep
    their interface while the hot paths never touch Python objects.
    Mutating the materialized lists is not supported — build a new plan.
    """

    def __init__(
        self,
        strategy: str,
        cluster: ClusterSpec,
        rank_sizes: List[int],
        files: Dict[str, int],
        writes: Optional[List[WriteItem]] = None,
        sends: Optional[List[SendItem]] = None,
        scan_meta: Optional[ScanMeta] = None,
        n_rounds: int = 1,
        barrier_per_round: bool = False,
        leaders: Optional[LeaderAssignment] = None,
        synchronous: bool = False,
        stripe_disjoint: bool = False,
        meta: Optional[Dict[str, object]] = None,
        arrays: Optional[PlanArrays] = None,
    ) -> None:
        if writes is None and arrays is None:
            raise ValueError("FlushPlan requires writes items or arrays")
        self.strategy = strategy
        self.cluster = cluster
        self.rank_sizes = rank_sizes
        self.files = files
        self.scan_meta = scan_meta
        self.n_rounds = n_rounds
        self.barrier_per_round = barrier_per_round
        self.leaders = leaders
        self.synchronous = synchronous
        self.stripe_disjoint = stripe_disjoint
        self.meta: Dict[str, object] = {} if meta is None else meta
        self.arrays = arrays
        self._writes = writes
        self._sends = sends if sends is not None else ([] if arrays is None else None)

    # -- item views (lazy) -----------------------------------------------
    @property
    def writes(self) -> List[WriteItem]:
        if self._writes is None:
            self._writes = self.arrays.to_write_items()
        return self._writes

    @property
    def sends(self) -> List[SendItem]:
        if self._sends is None:
            self._sends = self.arrays.to_send_items()
        return self._sends

    def ensure_arrays(self) -> PlanArrays:
        """Columnar view, built from the item lists if necessary."""
        if self.arrays is None:
            self.arrays = PlanArrays.from_items(
                self._writes or [], self._sends or [], file_names=list(self.files)
            )
        return self.arrays

    # -- derived ---------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return sum(self.rank_sizes)

    @property
    def n_files(self) -> int:
        return len(self.files)

    def writes_by_backend(self) -> Dict[int, List[WriteItem]]:
        out: Dict[int, List[WriteItem]] = defaultdict(list)
        for w in self.writes:
            out[w.backend].append(w)
        return dict(out)

    def sends_by_edge(self) -> Dict[Tuple[int, int], int]:
        out: Dict[Tuple[int, int], int] = defaultdict(int)
        for s in self.sends:
            out[(s.src_backend, s.dst_backend)] += s.size
        return dict(out)

    def network_bytes(self) -> int:
        if self.arrays is not None:
            return int(self.arrays.sends.size.sum())
        return sum(s.size for s in self.sends)

    def metadata_ops(self) -> int:
        """File create (once per file) + open (once per (backend, file))."""
        if self.arrays is not None:
            w = self.arrays.writes
            n_files = max(1, len(self.arrays.file_names))
            opens = np.unique(w.backend * n_files + w.file_id)
            return len(self.files) + len(opens)
        opens = {(w.backend, w.file) for w in self.writes}
        return len(self.files) + len(opens)


class PlanError(AssertionError):
    pass


# ---------------------------------------------------------------------------
# Columnar validation
# ---------------------------------------------------------------------------


def _union_segments(
    group: np.ndarray, start: np.ndarray, end: np.ndarray, span: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Merged (touching) interval union per group, on encoded coordinates.

    Inputs must be sorted by (group, start); positions must be < span and
    ``group.max() * span`` must fit in int64 (the caller guards this).
    Returns encoded (seg_start, seg_end) arrays, globally sorted.
    """
    za = group * span + start
    zb = group * span + end
    run_end = np.maximum.accumulate(zb)
    new_seg = np.empty(len(za), bool)
    new_seg[0] = True
    new_seg[1:] = za[1:] > run_end[:-1]
    seg_starts = np.flatnonzero(new_seg)
    return za[seg_starts], np.maximum.reduceat(zb, seg_starts)


def validate_plan(plan: FlushPlan) -> None:
    """Structural invariants every strategy must satisfy (columnar).

    Same acceptance set as :func:`validate_plan_reference` (the original
    item-loop validator, kept as the executable spec), but expressed as
    sorted-array / difference assertions so that 10^5+-row plans validate
    in milliseconds.
    """
    cluster = plan.cluster
    n_ranks = cluster.world_size
    if len(plan.rank_sizes) != n_ranks:
        raise PlanError("rank_sizes length mismatch")
    if plan._writes is not None or plan._sends is not None:
        # An item view exists and may have been mutated: treat the items
        # as the source of truth rather than a possibly-stale cached
        # PlanArrays (columnar-built plans that never materialized items
        # keep the zero-copy fast path).  The properties materialize the
        # not-yet-touched view from the cached arrays, which are still
        # in sync for it.
        pa = PlanArrays.from_items(
            plan.writes, plan.sends, file_names=list(plan.files)
        )
        plan.arrays = pa
    else:
        pa = plan.ensure_arrays()
    w, s = pa.writes, pa.sends
    nw = len(w)
    rank_sizes = _i64(plan.rank_sizes)
    n_files = len(pa.file_names)

    # 0. Column sanity (the item dataclasses enforce this in __post_init__;
    #    columnar builders bypass them, so assert here).
    if nw:
        if int(w.size.min()) <= 0:
            raise PlanError("write size must be positive")
        if int(w.file_offset.min()) < 0 or int(w.src_offset.min()) < 0:
            raise PlanError("write offsets must be non-negative")
        lo, hi = int(w.src_rank.min()), int(w.src_rank.max())
        if lo < 0 or hi >= n_ranks:
            raise PlanError(f"write references bad rank {lo if lo < 0 else hi}")
        if int(w.file_id.min()) < 0 or int(w.file_id.max()) >= n_files:
            raise PlanError("write references file id outside the file table")
    for f in np.unique(w.file_id).tolist():
        if pa.file_names[f] not in plan.files:
            raise PlanError(f"write targets undeclared file {pa.file_names[f]}")

    # 1. Source coverage: for each rank, the union of write src slices is
    #    exactly [0, size) with no overlap.  Sorted by (rank, src_offset),
    #    slices must chain: group starts at 0, each next offset equals the
    #    previous end, and total covered bytes equal the rank size.
    covered = np.zeros(n_ranks, np.int64)
    if nw:
        np.add.at(covered, w.src_rank, w.size)
        order = np.lexsort((w.src_offset, w.src_rank))
        r = w.src_rank[order]
        a = w.src_offset[order]
        b = a + w.size[order]
        first = np.empty(nw, bool)
        first[0] = True
        first[1:] = r[1:] != r[:-1]
        nonzero_start = a[first] != 0
        if nonzero_start.any():
            bad = int(r[first][np.flatnonzero(nonzero_start)[0]])
            raise PlanError(f"rank {bad}: src gap/overlap at 0")
        chain = ~first[1:]
        bad_chain = chain & (a[1:] != b[:-1])
        if bad_chain.any():
            i = int(np.flatnonzero(bad_chain)[0])
            raise PlanError(
                f"rank {int(r[i + 1])}: src gap/overlap at {int(b[i])} "
                f"(next slice {int(a[i + 1])})"
            )
    empties = (rank_sizes == 0) & (covered > 0)
    if empties.any():
        raise PlanError(f"rank {int(np.flatnonzero(empties)[0])} is empty but has writes")
    short = covered != rank_sizes
    if short.any():
        bad = int(np.flatnonzero(short)[0])
        raise PlanError(
            f"rank {bad}: covered {int(covered[bad])} of {int(rank_sizes[bad])} bytes"
        )

    # 2. Destination disjointness within each file: sorted by
    #    (file, file_offset), neighbours must not overlap and every write
    #    must end within the declared file size.
    if nw:
        order2 = np.lexsort((w.file_offset, w.file_id))
        f2 = w.file_id[order2]
        fo = w.file_offset[order2]
        fe = fo + w.size[order2]
        same_file = f2[1:] == f2[:-1]
        if (same_file & (fo[1:] < fe[:-1])).any():
            i = int(np.flatnonzero(same_file & (fo[1:] < fe[:-1]))[0])
            raise PlanError(f"file {pa.file_names[int(f2[i])]}: overlapping writes")
        fsizes = _i64([plan.files.get(nm, 0) for nm in pa.file_names])
        over = fe > fsizes[f2]
        if over.any():
            i = int(np.flatnonzero(over)[0])
            raise PlanError(f"file {pa.file_names[int(f2[i])]}: write past declared size")

    # 3. Every write executed by a backend that doesn't hold the source
    #    rank must be fed by sends covering exactly those bytes.
    home_w = cluster.nodes_of_ranks(w.src_rank)
    if len(s):
        if int(s.size.min()) <= 0:
            raise PlanError("send size must be positive")
        if int(s.src_offset.min()) < 0:
            raise PlanError("send offsets must be non-negative")
        if int(s.src_rank.min()) < 0 or int(s.src_rank.max()) >= n_ranks:
            raise PlanError("send references bad rank")
        if (s.src_backend != cluster.nodes_of_ranks(s.src_rank)).any():
            raise PlanError("send must originate at the rank's home backend")
    need = home_w != w.backend
    if need.any():
        _check_send_coverage(plan, pa, need, n_ranks)

    # 4. Stripe disjointness when claimed: with per-file disjointness
    #    already established, a stripe has two writers iff some pair of
    #    offset-adjacent writes in the same file straddles/shares a stripe
    #    with different backends.
    if plan.stripe_disjoint and nw:
        stripe = cluster.pfs.stripe_size
        b2 = w.backend[order2]
        sz2 = w.size[order2]
        last_stripe = (fo + sz2 - 1) // stripe
        first_stripe = fo // stripe
        conflict = same_file & (b2[1:] != b2[:-1]) & (first_stripe[1:] == last_stripe[:-1])
        if conflict.any():
            i = int(np.flatnonzero(conflict)[0])
            raise PlanError(
                f"stripe ({pa.file_names[int(f2[i])]},{int(last_stripe[i])}) "
                f"written by backends {int(b2[i])} and {int(b2[i + 1])} "
                f"despite stripe_disjoint"
            )


def _check_send_coverage(
    plan: FlushPlan, pa: PlanArrays, need: np.ndarray, n_ranks: int
) -> None:
    w, s = pa.writes, pa.sends
    nk = w.backend[need] * n_ranks + w.src_rank[need]
    na = w.src_offset[need]
    nb = na + w.size[need]
    if not len(s):
        key = int(nk[0])
        raise PlanError(
            f"backend {key // n_ranks} writes rank {key % n_ranks} bytes "
            f"[{int(na[0])},{int(nb[0])}) without a covering send"
        )
    gk = s.dst_backend * n_ranks + s.src_rank
    ga = s.src_offset
    gb = ga + s.size
    # Compact (backend, rank) keys to group ids so the encoded coordinate
    # group * span + position fits in int64.
    uk, inv = np.unique(np.concatenate((gk, nk)), return_inverse=True)
    g_got, g_need = inv[: len(gk)], inv[len(gk):]
    span = int(max(int(gb.max()), int(nb.max()))) + 1
    if len(uk) * span >= (1 << 62):  # pragma: no cover - astronomically large
        _send_coverage_reference(plan)
        return
    order = np.lexsort((ga, g_got))
    seg_a, seg_b = _union_segments(g_got[order], ga[order], gb[order], span)
    zq_a = g_need * span + na
    zq_b = g_need * span + nb
    pos = np.searchsorted(seg_a, zq_a, side="right") - 1
    cpos = np.maximum(pos, 0)
    ok = (pos >= 0) & (seg_a[cpos] // span == g_need) & (zq_b <= seg_b[cpos])
    if not ok.all():
        i = int(np.flatnonzero(~ok)[0])
        key = int(uk[g_need[i]])
        raise PlanError(
            f"backend {key // n_ranks} writes rank {key % n_ranks} bytes "
            f"[{int(na[i])},{int(nb[i])}) without a covering send"
        )


def _send_coverage_reference(plan: FlushPlan) -> None:
    """Item-loop send-coverage check (fallback + executable spec)."""
    cluster = plan.cluster
    needed: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
    for w in plan.writes:
        home = cluster.node_of_rank(w.src_rank)
        if home != w.backend:
            needed[(w.backend, w.src_rank)].append(
                (w.src_offset, w.src_offset + w.size)
            )
    got: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
    for s in plan.sends:
        home = cluster.node_of_rank(s.src_rank)
        if s.src_backend != home:
            raise PlanError("send must originate at the rank's home backend")
        got[(s.dst_backend, s.src_rank)].append(
            (s.src_offset, s.src_offset + s.size)
        )

    def _union(ivs: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
        out: List[Tuple[int, int]] = []
        for a, b in sorted(ivs):
            if out and a <= out[-1][1]:
                out[-1] = (out[-1][0], max(out[-1][1], b))
            else:
                out.append((a, b))
        return out

    for key, ivs in needed.items():
        have = _union(got.get(key, []))
        for a, b in _union(ivs):
            if not any(ha <= a and b <= hb for ha, hb in have):
                raise PlanError(
                    f"backend {key[0]} writes rank {key[1]} bytes "
                    f"[{a},{b}) without a covering send"
                )


def validate_plan_reference(plan: FlushPlan) -> None:
    """The original item-loop validator — the spec the columnar
    :func:`validate_plan` is tested against (see tests/test_plan_arrays.py)."""
    cluster = plan.cluster
    n_ranks = cluster.world_size
    if len(plan.rank_sizes) != n_ranks:
        raise PlanError("rank_sizes length mismatch")

    # 1. Source coverage: for each rank, the union of write src slices is
    #    exactly [0, size) with no overlap.
    per_rank: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for w in plan.writes:
        if not (0 <= w.src_rank < n_ranks):
            raise PlanError(f"write references bad rank {w.src_rank}")
        per_rank[w.src_rank].append((w.src_offset, w.src_offset + w.size))
    for rank in range(n_ranks):
        size = plan.rank_sizes[rank]
        ivs = sorted(per_rank.get(rank, []))
        if size == 0:
            if ivs:
                raise PlanError(f"rank {rank} is empty but has writes")
            continue
        pos = 0
        for a, b in ivs:
            if a != pos:
                raise PlanError(
                    f"rank {rank}: src gap/overlap at {pos} (next slice {a})"
                )
            pos = b
        if pos != size:
            raise PlanError(f"rank {rank}: covered {pos} of {size} bytes")

    # 2. Destination disjointness within each file.
    per_file: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    for w in plan.writes:
        if w.file not in plan.files:
            raise PlanError(f"write targets undeclared file {w.file}")
        per_file[w.file].append((w.file_offset, w.file_offset + w.size))
    for fname, ivs in per_file.items():
        ivs.sort()
        for (a0, b0), (a1, b1) in zip(ivs, ivs[1:]):
            if a1 < b0:
                raise PlanError(f"file {fname}: overlapping writes")
        if ivs and ivs[-1][1] > plan.files[fname]:
            raise PlanError(f"file {fname}: write past declared size")

    # 3. Send coverage for non-local writes.
    _send_coverage_reference(plan)

    # 4. Stripe disjointness when claimed.
    if plan.stripe_disjoint:
        stripe = cluster.pfs.stripe_size
        owner: Dict[Tuple[str, int], int] = {}
        for w in plan.writes:
            s0 = w.file_offset // stripe
            s1 = (w.file_offset + w.size - 1) // stripe
            for st in range(s0, s1 + 1):
                prev = owner.setdefault((w.file, st), w.backend)
                if prev != w.backend:
                    raise PlanError(
                        f"stripe ({w.file},{st}) written by backends "
                        f"{prev} and {w.backend} despite stripe_disjoint"
                    )


# ---------------------------------------------------------------------------
# Read side: FileLayout (the inverse of a flush) + columnar ReadPlan
# ---------------------------------------------------------------------------


def stored_space_offsets(stored_sizes: Sequence[int]) -> np.ndarray:
    """Exclusive prefix sum of per-rank stored sizes: rank -> global
    stored-space offset of that rank's blob (len = n_ranks + 1; the last
    entry is the total stored bytes)."""
    sizes = _i64(stored_sizes)
    out = np.zeros(len(sizes) + 1, np.int64)
    np.cumsum(sizes, out=out[1:])
    return out


@dataclass
class FileLayout:
    """Extent table: where every stored-space byte lives on the PFS.

    The inverse view of a flush — each row maps a contiguous stored-space
    interval onto a contiguous file extent.  Columns (parallel int64,
    sorted by ``start`` after construction):

    * ``start``       — global stored-space offset of the extent
    * ``size``        — extent length (> 0)
    * ``file_id``     — index into ``file_names``
    * ``file_offset`` — byte offset inside that file

    Invariant: the extents tile ``[0, total)`` exactly — sorted by
    ``start``, each extent begins where the previous ends.  This is the
    read-side restatement of the flush validator's *source coverage*
    rule, and ``__post_init__`` enforces it, so any FlushPlan that
    passed :func:`validate_plan` inverts to a valid layout.
    """

    file_names: List[str]
    files: Dict[str, int]
    start: np.ndarray
    size: np.ndarray
    file_id: np.ndarray
    file_offset: np.ndarray
    total: int

    def __post_init__(self):
        self.start = _i64(self.start)
        self.size = _i64(self.size)
        self.file_id = _i64(self.file_id)
        self.file_offset = _i64(self.file_offset)
        self.total = int(self.total)
        n = len(self.start)
        if len({n, len(self.size), len(self.file_id), len(self.file_offset)}) != 1:
            raise PlanError("FileLayout columns must have identical length")
        if n == 0:
            if self.total != 0:
                raise PlanError("empty layout must cover 0 bytes")
            return
        order = np.argsort(self.start, kind="stable")
        for c in ("start", "size", "file_id", "file_offset"):
            setattr(self, c, getattr(self, c)[order])
        if int(self.size.min()) <= 0:
            raise PlanError("layout extent sizes must be positive")
        if int(self.start[0]) != 0:
            raise PlanError("layout does not start at stored offset 0")
        ends = self.start + self.size
        if (self.start[1:] != ends[:-1]).any():
            i = int(np.flatnonzero(self.start[1:] != ends[:-1])[0])
            raise PlanError(
                f"layout gap/overlap at stored offset {int(ends[i])} "
                f"(next extent {int(self.start[i + 1])})"
            )
        if int(ends[-1]) != self.total:
            raise PlanError(
                f"layout covers {int(ends[-1])} of {self.total} stored bytes"
            )

    def __len__(self) -> int:
        return len(self.start)

    @staticmethod
    def from_flush_plan(plan: FlushPlan) -> "FileLayout":
        """Invert a flush: writes become extents keyed by stored offset.

        Works for every strategy — the write columns already carry
        ``(src_rank, src_offset)``; adding the rank's stored-space base
        offset turns them into global stored coordinates.
        """
        pa = plan.ensure_arrays()
        w = pa.writes
        offsets = stored_space_offsets(plan.rank_sizes)
        return FileLayout(
            file_names=list(pa.file_names),
            files=dict(plan.files),
            start=offsets[w.src_rank] + w.src_offset,
            size=w.size.copy(),
            file_id=w.file_id.copy(),
            file_offset=w.file_offset.copy(),
            total=int(offsets[-1]),
        )

    @staticmethod
    def from_placement(
        placement,
        stored_sizes: Sequence[int],
        files: Dict[str, int],
    ) -> "FileLayout":
        """Build from a manifest placement (the persisted form of a
        flush's write set): either the columnar
        :class:`~repro.core.serialize.Placement` (one gather, no loop)
        or the legacy rank -> [(file, file_offset, src_offset, size)]
        dict of tuples."""
        offsets = stored_space_offsets(stored_sizes)
        if hasattr(placement, "rank"):  # columnar Placement
            return FileLayout(
                file_names=list(placement.file_names),
                files=dict(files),
                start=offsets[placement.rank] + placement.src_offset,
                size=placement.size.copy(),
                file_id=placement.file_id.copy(),
                file_offset=placement.file_offset.copy(),
                total=int(offsets[-1]),
            )
        names: List[str] = []
        fid: Dict[str, int] = {}
        start: List[int] = []
        size: List[int] = []
        file_id: List[int] = []
        file_offset: List[int] = []
        for rank, entries in placement.items():
            base = int(offsets[rank])
            for fname, foff, soff, n in entries:
                j = fid.get(fname)
                if j is None:
                    j = fid[fname] = len(names)
                    names.append(fname)
                start.append(base + soff)
                size.append(n)
                file_id.append(j)
                file_offset.append(foff)
        return FileLayout(
            file_names=names,
            files=dict(files),
            start=start,
            size=size,
            file_id=file_id,
            file_offset=file_offset,
            total=int(offsets[-1]),
        )


_R_COLS = ("reader", "file_id", "file_offset", "size", "dst_req", "dst_offset")


@dataclass
class ReadColumns:
    """Parallel int64 columns, one row per ranged read (see module doc)."""

    reader: np.ndarray
    file_id: np.ndarray
    file_offset: np.ndarray
    size: np.ndarray
    dst_req: np.ndarray
    dst_offset: np.ndarray

    def __post_init__(self):
        for name in _R_COLS:
            setattr(self, name, _i64(getattr(self, name)))
        if len({getattr(self, c).shape for c in _R_COLS}) != 1:
            raise ValueError("ReadColumns columns must have identical length")

    def __len__(self) -> int:
        return len(self.reader)

    @staticmethod
    def empty() -> "ReadColumns":
        z = np.empty(0, np.int64)
        return ReadColumns(z, z, z, z, z, z)

    def take(self, idx: np.ndarray) -> "ReadColumns":
        return ReadColumns(*(getattr(self, c)[idx] for c in _R_COLS))


def coalesce_read_columns(r: ReadColumns) -> ReadColumns:
    """Merge runs contiguous in both file and destination coordinates.

    The read twin of :func:`coalesce_write_columns`: one ``np.lexsort``
    plus a boundary-difference pass.  Two sorted rows merge when they
    serve the same (reader, request, file) and both the file offset and
    the destination offset chain."""
    if len(r) <= 1:
        return r
    order = np.lexsort((r.dst_offset, r.file_id, r.dst_req, r.reader))
    b = r.take(order)
    same = (
        (b.reader[1:] == b.reader[:-1])
        & (b.dst_req[1:] == b.dst_req[:-1])
        & (b.file_id[1:] == b.file_id[:-1])
        & (b.dst_offset[1:] == b.dst_offset[:-1] + b.size[:-1])
        & (b.file_offset[1:] == b.file_offset[:-1] + b.size[:-1])
    )
    starts = np.flatnonzero(np.concatenate(([True], ~same)))
    return ReadColumns(
        reader=b.reader[starts],
        file_id=b.file_id[starts],
        file_offset=b.file_offset[starts],
        size=np.add.reduceat(b.size, starts),
        dst_req=b.dst_req[starts],
        dst_offset=b.dst_offset[starts],
    )


@dataclass
class ReadPlan:
    """One restore/reshard, columnar: ranged reads + the request table.

    ``req_start``/``req_size``/``req_reader`` describe the consumer's
    byte-range requests against stored space (one destination buffer per
    request); ``reads`` lists the ranged ``pread``s that fill them.
    """

    file_names: List[str]
    files: Dict[str, int]
    reads: ReadColumns
    req_start: np.ndarray
    req_size: np.ndarray
    req_reader: np.ndarray
    meta: Dict[str, object]

    def __post_init__(self):
        self.req_start = _i64(self.req_start)
        self.req_size = _i64(self.req_size)
        self.req_reader = _i64(self.req_reader)

    @property
    def n_reads(self) -> int:
        return len(self.reads)

    @property
    def n_requests(self) -> int:
        return len(self.req_start)

    @property
    def total_bytes(self) -> int:
        return int(self.req_size.sum())

    def reads_per_reader(self) -> Dict[int, int]:
        u, c = np.unique(self.reads.reader, return_counts=True)
        return dict(zip(u.tolist(), c.tolist()))


def merge_intervals(
    start: Sequence[int], size: Sequence[int]
) -> Tuple[np.ndarray, np.ndarray]:
    """Union of half-open intervals ``[start_i, start_i + size_i)``.

    Returns sorted, disjoint, merged ``(starts, sizes)``; zero-size
    inputs are dropped.  Pure array program (sort + running-max
    boundary pass).  The chunk-granular restore path uses this to turn
    the stored-space extents of the needed chunks into a minimal set of
    :func:`build_read_plan` requests — adjacent chunks of one rank
    coalesce into a single ranged request before the planner ever sees
    them.
    """
    a = _i64(start)
    s = _i64(size)
    if len(a) != len(s):
        raise PlanError("merge_intervals: start and size length mismatch")
    keep = s > 0
    a, s = a[keep], s[keep]
    if not len(a):
        z = np.empty(0, np.int64)
        return z, z
    order = np.argsort(a, kind="stable")
    a, b = a[order], (a + s)[order]
    run_end = np.maximum.accumulate(b)
    new_seg = np.empty(len(a), bool)
    new_seg[0] = True
    new_seg[1:] = a[1:] > run_end[:-1]
    starts = a[new_seg]
    ends = np.maximum.reduceat(b, np.flatnonzero(new_seg))
    return starts, ends - starts


def assign_readers(
    stored_sizes: Sequence[int],
    n_readers: int,
    *,
    weights: Optional[Sequence[float]] = None,
) -> np.ndarray:
    """Balanced contiguous assignment of producer ranks to consumer nodes.

    Rank r goes to the reader whose byte share contains the midpoint of
    r's blob, so each of the ``n_readers`` consumers pulls ~equal bytes
    even when blob sizes are skewed.  Pure array program.

    ``weights`` (optional, one per reader, positive) skews the byte
    shares: a reader with weight 0.5 receives half the bytes of a
    weight-1.0 peer.  The health registry's straggler demotion feeds
    observed per-reader latency ratios through here so a slow node
    serves fewer extents instead of gating the whole restore.  With
    ``weights=None`` (or all-equal weights) the assignment is exactly
    the unweighted midpoint rule above — byte-identical plans."""
    sizes = _i64(stored_sizes)
    n_readers = max(1, int(n_readers))
    offsets = stored_space_offsets(sizes)
    total = int(offsets[-1])
    if total == 0:
        return np.zeros(len(sizes), np.int64)
    mid = offsets[:-1] + sizes // 2
    if weights is not None:
        w = np.asarray(weights, np.float64)
        if len(w) != n_readers:
            raise PlanError("assign_readers: one weight per reader required")
        if (w <= 0).any():
            raise PlanError("assign_readers: weights must be positive")
        if not np.allclose(w, w[0]):
            # reader k covers stored space (cum[k-1], cum[k]] of the
            # weight-proportional partition of [0, total]
            bounds = np.cumsum(w) * (total / float(w.sum()))
            return np.minimum(
                np.searchsorted(bounds, mid, side="right"), n_readers - 1
            ).astype(np.int64)
    return np.minimum(mid * n_readers // total, n_readers - 1)


def _cut_at_extents(
    layout: FileLayout, a: np.ndarray, b: np.ndarray
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Subdivide stored-space intervals ``[a_i, b_i)`` at layout extent
    boundaries (two ``np.searchsorted`` calls + the repeat/arange trick).

    Returns ``(idx, eidx, p_start, p_end)`` per piece: source-interval
    index, extent index, piece bounds.  Zero-length intervals produce no
    pieces.  Callers guarantee intervals lie within ``[0, layout.total]``
    — this is the single subdivision used by both the builder and the
    validator, so they can never disagree about where extents cut.
    """
    nz = b > a
    first = np.searchsorted(layout.start, a, side="right") - 1
    last = np.searchsorted(layout.start, b - 1, side="right") - 1
    n_pieces = np.where(nz, last - first + 1, 0)
    total = int(n_pieces.sum())
    idx = np.repeat(np.arange(len(a), dtype=np.int64), n_pieces)
    base = np.cumsum(n_pieces) - n_pieces
    within = np.arange(total, dtype=np.int64) - np.repeat(base, n_pieces)
    eidx = first[idx] + within
    p_start = np.maximum(a[idx], layout.start[eidx])
    p_end = np.minimum(b[idx], layout.start[eidx] + layout.size[eidx])
    return idx, eidx, p_start, p_end


def build_read_plan(
    layout: FileLayout,
    req_start: Sequence[int],
    req_size: Sequence[int],
    req_reader: Optional[Sequence[int]] = None,
    *,
    coalesce: bool = True,
    validate: bool = True,
) -> ReadPlan:
    """Map consumer byte-range requests onto aggregated-file extents.

    The read-side twin of the columnar strategy builders: requests are
    cut at layout-extent boundaries with two ``np.searchsorted`` calls
    plus the repeat/arange subdivision trick — no per-request Python
    loop — so planning a paper-scale restore (10^5 requests against 10^5
    extents) is an array program.

    Requests may target any subset of stored space, in any order, with
    any consumer geometry (this is what makes N-rank save -> M-rank
    restore and partial per-leaf restore the same operation); zero-size
    requests are legal and produce no reads.
    """
    qa = _i64(req_start)
    qs = _i64(req_size)
    n_req = len(qa)
    if len(qs) != n_req:
        raise PlanError("req_start and req_size must have identical length")
    readers = (
        np.zeros(n_req, np.int64) if req_reader is None else _i64(req_reader)
    )
    if len(readers) != n_req:
        raise PlanError("req_reader must have one entry per request")
    if n_req:
        if int(qs.min()) < 0:
            raise PlanError("request sizes must be non-negative")
        if int(qa.min()) < 0 or int((qa + qs).max()) > layout.total:
            raise PlanError("request outside stored space")
    qb = qa + qs
    if not len(layout) or not (qs > 0).any():
        reads = ReadColumns.empty()
    else:
        ridx, eidx, p_start, p_end = _cut_at_extents(layout, qa, qb)
        reads = ReadColumns(
            reader=readers[ridx],
            file_id=layout.file_id[eidx],
            file_offset=layout.file_offset[eidx] + (p_start - layout.start[eidx]),
            size=p_end - p_start,
            dst_req=ridx,
            dst_offset=p_start - qa[ridx],
        )
        if coalesce:
            reads = coalesce_read_columns(reads)
    rp = ReadPlan(
        file_names=list(layout.file_names),
        files=dict(layout.files),
        reads=reads,
        req_start=qa,
        req_size=qs,
        req_reader=readers,
        meta={"n_extents": len(layout), "stored_total": layout.total},
    )
    if validate:
        validate_read_plan(rp, layout)
    return rp


def validate_read_plan(rp: ReadPlan, layout: Optional[FileLayout] = None) -> None:
    """Structural invariants of a read plan (columnar throughout).

    Checks the three rules from the module doc: per-request destination
    coverage (tile ``[0, req_size)`` exactly), in-bounds file reads, and
    — when ``layout`` is given — that every read's file extent is where
    the layout places the request's stored bytes."""
    r = rp.reads
    nr = len(r)
    n_req = rp.n_requests
    n_files = len(rp.file_names)

    if nr:
        if int(r.size.min()) <= 0:
            raise PlanError("read size must be positive")
        if int(r.file_offset.min()) < 0 or int(r.dst_offset.min()) < 0:
            raise PlanError("read offsets must be non-negative")
        if int(r.dst_req.min()) < 0 or int(r.dst_req.max()) >= n_req:
            raise PlanError("read references request outside the request table")
        if int(r.file_id.min()) < 0 or int(r.file_id.max()) >= n_files:
            raise PlanError("read references file id outside the file table")

    # 1. Destination coverage: per request, dst slices tile [0, req_size).
    covered = np.zeros(n_req, np.int64)
    if nr:
        np.add.at(covered, r.dst_req, r.size)
        order = np.lexsort((r.dst_offset, r.dst_req))
        q = r.dst_req[order]
        a = r.dst_offset[order]
        b = a + r.size[order]
        firstrow = np.empty(nr, bool)
        firstrow[0] = True
        firstrow[1:] = q[1:] != q[:-1]
        if (a[firstrow] != 0).any():
            bad = int(q[firstrow][np.flatnonzero(a[firstrow] != 0)[0]])
            raise PlanError(f"request {bad}: dst gap/overlap at 0")
        chain = ~firstrow[1:]
        bad_chain = chain & (a[1:] != b[:-1])
        if bad_chain.any():
            i = int(np.flatnonzero(bad_chain)[0])
            raise PlanError(
                f"request {int(q[i + 1])}: dst gap/overlap at {int(b[i])} "
                f"(next piece {int(a[i + 1])})"
            )
    short = covered != rp.req_size
    if short.any():
        bad = int(np.flatnonzero(short)[0])
        raise PlanError(
            f"request {bad}: reads cover {int(covered[bad])} of "
            f"{int(rp.req_size[bad])} bytes"
        )

    # 2. In-bounds file reads.
    if nr:
        fsizes = _i64([rp.files.get(nm, 0) for nm in rp.file_names])
        over = r.file_offset + r.size > fsizes[r.file_id]
        if over.any():
            i = int(np.flatnonzero(over)[0])
            raise PlanError(
                f"file {rp.file_names[int(r.file_id[i])]}: read past declared size"
            )

    # 3. Layout consistency: the stored position each read claims to fill
    #    must resolve (through the layout) to exactly the file extent the
    #    read targets.  Coalesced reads may legally span several extents
    #    that happen to be contiguous in the same file, so each read is
    #    first subdivided at extent boundaries (the builder's own
    #    :func:`_cut_at_extents`), then every piece is checked.
    if layout is not None and nr and len(layout):
        pos = rp.req_start[r.dst_req] + r.dst_offset
        end = pos + r.size
        if int(pos.min()) < 0 or int(end.max()) > layout.total:
            i = int(np.flatnonzero((pos < 0) | (end > layout.total))[0])
            raise PlanError(
                f"read {i} outside stored space at offset {int(pos[i])}"
            )
        ridx, eidx, p_start, _ = _cut_at_extents(layout, pos, end)
        ok = (layout.file_id[eidx] == r.file_id[ridx]) & (
            layout.file_offset[eidx] + (p_start - layout.start[eidx])
            == r.file_offset[ridx] + (p_start - pos[ridx])
        )
        if not ok.all():
            i = int(ridx[np.flatnonzero(~ok)[0]])
            raise PlanError(
                f"read {i} disagrees with the layout about stored offset "
                f"{int(pos[i])}"
            )


def count_false_sharing(plan: FlushPlan) -> Dict[str, int]:
    """Diagnostics: stripes touched by >1 backend (the paper's §2.1 issue)."""
    stripe = plan.cluster.pfs.stripe_size
    writers: Dict[Tuple[str, int], set] = defaultdict(set)
    for w in plan.writes:
        s0 = w.file_offset // stripe
        s1 = (w.file_offset + w.size - 1) // stripe
        for st in range(s0, s1 + 1):
            writers[(w.file, st)].add(w.backend)
    shared = {k: v for k, v in writers.items() if len(v) > 1}
    return {
        "stripes_total": len(writers),
        "stripes_shared": len(shared),
        "max_writers_per_stripe": max((len(v) for v in writers.values()), default=0),
        "excess_writers": sum(len(v) - 1 for v in shared.values()),
    }
