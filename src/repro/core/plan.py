"""FlushPlan: the executable description of one asynchronous flush.

An aggregation *strategy* is a pure function
``(ClusterSpec, rank_sizes) -> FlushPlan``.  The plan lists every byte
movement needed to move N rank-local checkpoints into M remote files:

* ``SendItem`` — a gather hop: bytes of some rank's checkpoint shipped
  from the active backend holding them to a leader backend (network).
* ``WriteItem`` — a PFS write issued by one backend: (file, offset, size)
  sourced from some rank's checkpoint blob at ``src_offset``.

Executors (real files / discrete-event simulator) consume plans without
knowing which strategy produced them — this is the co-design seam the
paper argues for: strategy decides *who writes what where*, the executor
and its contention model price/perform it.

Plans are also the verification surface: :func:`validate_plan` checks
conservation (every checkpoint byte written exactly once), send/write
consistency, and — for stripe-disjoint strategies — single-writer-per-
stripe.  Property-based tests fuzz these invariants.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.cluster import ClusterSpec
from repro.core.prefix_sum import LeaderAssignment, ScanMeta


@dataclass(frozen=True)
class WriteItem:
    backend: int        # node id of the active backend issuing the write
    file: str           # logical remote file name
    file_offset: int
    size: int
    src_rank: int       # whose checkpoint blob this slice comes from
    src_offset: int     # offset inside that rank's blob
    round: int = 0      # barrier round (MPI-IO multi-phase); 0 = unsynchronized

    def __post_init__(self):
        if self.size <= 0:
            raise ValueError("WriteItem.size must be positive")
        if self.file_offset < 0 or self.src_offset < 0:
            raise ValueError("offsets must be non-negative")


@dataclass(frozen=True)
class SendItem:
    src_backend: int
    dst_backend: int
    src_rank: int
    src_offset: int
    size: int
    round: int = 0

    def __post_init__(self):
        if self.size <= 0:
            raise ValueError("SendItem.size must be positive")


@dataclass
class FlushPlan:
    strategy: str
    cluster: ClusterSpec
    rank_sizes: List[int]
    files: Dict[str, int]                 # file -> logical size (bytes)
    writes: List[WriteItem]
    sends: List[SendItem] = field(default_factory=list)
    scan_meta: Optional[ScanMeta] = None  # coordination cost (None = no scan)
    n_rounds: int = 1
    barrier_per_round: bool = False       # MPI-IO collective semantics
    leaders: Optional[LeaderAssignment] = None
    synchronous: bool = False             # GIO-style: application blocked
    stripe_disjoint: bool = False         # claim: one writer per stripe
    meta: Dict[str, object] = field(default_factory=dict)

    # -- derived ---------------------------------------------------------
    @property
    def total_bytes(self) -> int:
        return sum(self.rank_sizes)

    @property
    def n_files(self) -> int:
        return len(self.files)

    def writes_by_backend(self) -> Dict[int, List[WriteItem]]:
        out: Dict[int, List[WriteItem]] = defaultdict(list)
        for w in self.writes:
            out[w.backend].append(w)
        return dict(out)

    def sends_by_edge(self) -> Dict[Tuple[int, int], int]:
        out: Dict[Tuple[int, int], int] = defaultdict(int)
        for s in self.sends:
            out[(s.src_backend, s.dst_backend)] += s.size
        return dict(out)

    def network_bytes(self) -> int:
        return sum(s.size for s in self.sends)

    def metadata_ops(self) -> int:
        """File create (once per file) + open (once per (backend, file))."""
        opens = {(w.backend, w.file) for w in self.writes}
        return len(self.files) + len(opens)


class PlanError(AssertionError):
    pass


def validate_plan(plan: FlushPlan) -> None:
    """Structural invariants every strategy must satisfy."""
    cluster = plan.cluster
    n_ranks = cluster.world_size
    if len(plan.rank_sizes) != n_ranks:
        raise PlanError("rank_sizes length mismatch")

    # 1. Source coverage: for each rank, the union of write src slices is
    #    exactly [0, size) with no overlap.
    per_rank: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
    for w in plan.writes:
        if not (0 <= w.src_rank < n_ranks):
            raise PlanError(f"write references bad rank {w.src_rank}")
        per_rank[w.src_rank].append((w.src_offset, w.src_offset + w.size))
    for rank in range(n_ranks):
        size = plan.rank_sizes[rank]
        ivs = sorted(per_rank.get(rank, []))
        if size == 0:
            if ivs:
                raise PlanError(f"rank {rank} is empty but has writes")
            continue
        pos = 0
        for a, b in ivs:
            if a != pos:
                raise PlanError(
                    f"rank {rank}: src gap/overlap at {pos} (next slice {a})"
                )
            pos = b
        if pos != size:
            raise PlanError(f"rank {rank}: covered {pos} of {size} bytes")

    # 2. Destination disjointness within each file.
    per_file: Dict[str, List[Tuple[int, int]]] = defaultdict(list)
    for w in plan.writes:
        if w.file not in plan.files:
            raise PlanError(f"write targets undeclared file {w.file}")
        per_file[w.file].append((w.file_offset, w.file_offset + w.size))
    for fname, ivs in per_file.items():
        ivs.sort()
        for (a0, b0), (a1, b1) in zip(ivs, ivs[1:]):
            if a1 < b0:
                raise PlanError(f"file {fname}: overlapping writes")
        if ivs and ivs[-1][1] > plan.files[fname]:
            raise PlanError(f"file {fname}: write past declared size")

    # 3. Every write executed by a backend that doesn't hold the source
    #    rank must be fed by sends covering exactly those bytes.
    needed: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
    for w in plan.writes:
        home = cluster.node_of_rank(w.src_rank)
        if home != w.backend:
            needed[(w.backend, w.src_rank)].append(
                (w.src_offset, w.src_offset + w.size)
            )
    got: Dict[Tuple[int, int], List[Tuple[int, int]]] = defaultdict(list)
    for s in plan.sends:
        home = cluster.node_of_rank(s.src_rank)
        if s.src_backend != home:
            raise PlanError("send must originate at the rank's home backend")
        got[(s.dst_backend, s.src_rank)].append(
            (s.src_offset, s.src_offset + s.size)
        )

    def _union(ivs: List[Tuple[int, int]]) -> List[Tuple[int, int]]:
        out: List[Tuple[int, int]] = []
        for a, b in sorted(ivs):
            if out and a <= out[-1][1]:
                out[-1] = (out[-1][0], max(out[-1][1], b))
            else:
                out.append((a, b))
        return out

    for key, ivs in needed.items():
        have = _union(got.get(key, []))
        for a, b in _union(ivs):
            if not any(ha <= a and b <= hb for ha, hb in have):
                raise PlanError(
                    f"backend {key[0]} writes rank {key[1]} bytes "
                    f"[{a},{b}) without a covering send"
                )

    # 4. Stripe disjointness when claimed.
    if plan.stripe_disjoint:
        stripe = cluster.pfs.stripe_size
        owner: Dict[Tuple[str, int], int] = {}
        for w in plan.writes:
            s0 = w.file_offset // stripe
            s1 = (w.file_offset + w.size - 1) // stripe
            for st in range(s0, s1 + 1):
                prev = owner.setdefault((w.file, st), w.backend)
                if prev != w.backend:
                    raise PlanError(
                        f"stripe ({w.file},{st}) written by backends "
                        f"{prev} and {w.backend} despite stripe_disjoint"
                    )


def count_false_sharing(plan: FlushPlan) -> Dict[str, int]:
    """Diagnostics: stripes touched by >1 backend (the paper's §2.1 issue)."""
    stripe = plan.cluster.pfs.stripe_size
    writers: Dict[Tuple[str, int], set] = defaultdict(set)
    for w in plan.writes:
        s0 = w.file_offset // stripe
        s1 = (w.file_offset + w.size - 1) // stripe
        for st in range(s0, s1 + 1):
            writers[(w.file, st)].add(w.backend)
    shared = {k: v for k, v in writers.items() if len(v) > 1}
    return {
        "stripes_total": len(writers),
        "stripes_shared": len(shared),
        "max_writers_per_stripe": max((len(v) for v in writers.values()), default=0),
        "excess_writers": sum(len(v) - 1 for v in shared.values()),
    }
