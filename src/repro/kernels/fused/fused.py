"""Pallas TPU kernel: fused XOR-delta + per-chunk dirty count + checksum.

One grid step per *chunk* (``CheckpointConfig.chunk_size`` bytes =
``tiles_per_chunk`` native ``(8, 128)`` uint32 tiles), one pass over
both streams.  Each step emits:

* the XOR delta of its chunk (``kernels/delta`` semantics),
* the changed-word count (``> 0`` == the chunk is dirty), and
* the two-track checksum partials ``(S, T)`` of the *current* chunk —
  the same function as ``kernels/checksum`` restarted at every chunk
  boundary, so the pair digests the chunk exactly like
  ``checksum_u32`` over the chunk's words alone.

Fusing the three saves two extra HBM sweeps over the full state: the
separate delta + per-chunk checksum composition reads the streams once
per kernel, and at checkpoint sizes the pass is purely
HBM-bandwidth-bound.  The position index is computed in-kernel from the
tile/row/col iotas and reduced mod ``IDX_MOD`` (a power of two, so a
bitwise AND), keeping every product exact in uint32 before the
deliberate wrap-around accumulation — identical to the numpy oracle in
``ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.checksum.ref import IDX_MOD

TILE_ROWS = 8
TILE_COLS = 128
TILE = TILE_ROWS * TILE_COLS  # 1024 uint32 words per native tile


def _fused_kernel(c_ref, b_ref, d_ref, m_ref):
    c = c_ref[0]  # (tiles_per_chunk, 8, 128) uint32, the current chunk
    b = b_ref[0]  # same shape, the base snapshot's chunk
    d = jnp.bitwise_xor(c, b)
    d_ref[0] = d
    shape = c.shape
    tile = jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    rows = jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    cols = jax.lax.broadcasted_iota(jnp.uint32, shape, 2)
    idx = (
        tile * jnp.uint32(TILE)
        + rows * jnp.uint32(TILE_COLS)
        + cols
    ) & jnp.uint32(IDX_MOD - 1)
    m_ref[0, 0] = jnp.sum((d != 0).astype(jnp.uint32), dtype=jnp.uint32)
    m_ref[0, 1] = jnp.sum(c, dtype=jnp.uint32)
    m_ref[0, 2] = jnp.sum(idx * c, dtype=jnp.uint32)


def fused_chunk_tiles(cur: jnp.ndarray, base: jnp.ndarray, *, interpret: bool):
    """(n_chunks, tiles_per_chunk, 8, 128) u32 x2 ->
    (delta same shape, meta (n_chunks, 3) u32 = (changed, S, T))."""
    n, t = cur.shape[0], cur.shape[1]
    return pl.pallas_call(
        _fused_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, t, TILE_ROWS, TILE_COLS), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, t, TILE_ROWS, TILE_COLS), lambda g: (g, 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, t, TILE_ROWS, TILE_COLS), lambda g: (g, 0, 0, 0)),
            pl.BlockSpec((1, 3), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, t, TILE_ROWS, TILE_COLS), jnp.uint32),
            jax.ShapeDtypeStruct((n, 3), jnp.uint32),
        ],
        interpret=interpret,
    )(cur, base)
