"""Numpy oracle for the fused delta+dirty+checksum chunk pass.

Pure numpy (no jax) so it can double as the host-side verifier: the
engine's digest column check recomputes exactly this per decoded chunk.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.checksum.ref import IDX_MOD

_MASK32 = np.uint64(0xFFFFFFFF)


def _pad_chunks(w: np.ndarray, chunk_words: int) -> np.ndarray:
    w = np.ascontiguousarray(w, dtype=np.uint32).reshape(-1)
    rem = (-w.size) % chunk_words
    if rem:
        w = np.concatenate([w, np.zeros(rem, dtype=np.uint32)])
    return w.reshape(-1, chunk_words)


def chunk_digests_ref(words: np.ndarray, chunk_words: int) -> np.ndarray:
    """Per-chunk ``(T << 32) | S`` two-track digests, zero-padded tail.

    Matches ``repro.kernels.checksum.ref.digest_ref`` applied to each
    chunk's words in isolation (the index track restarts at every chunk
    boundary).  Accumulation is plain uint64 arithmetic: products are at
    most ``2**52`` and the final ``& 0xffffffff`` is exact under mod-2**64
    wrap-around because ``2**32`` divides ``2**64``.
    """
    c = _pad_chunks(words, chunk_words).astype(np.uint64)
    idx = (np.arange(chunk_words, dtype=np.uint64) % np.uint64(IDX_MOD))
    s = c.sum(axis=1) & _MASK32
    t = (c * idx[None, :]).sum(axis=1) & _MASK32
    return (t << np.uint64(32)) | s


def fused_ref(cur: np.ndarray, base: np.ndarray, chunk_words: int):
    """Oracle for ``fused_precodec``.

    Returns ``(delta, counts, digests)`` where ``delta`` is the XOR of
    the zero-padded streams shaped ``(n_chunks, chunk_words)`` uint32,
    ``counts`` the per-chunk changed-word totals (uint32) and
    ``digests`` the per-chunk two-track digests of *cur* (uint64).
    """
    c = _pad_chunks(cur, chunk_words)
    b = _pad_chunks(base, chunk_words)
    if c.shape != b.shape:
        raise ValueError(f"stream length mismatch: {c.shape} vs {b.shape}")
    d = np.bitwise_xor(c, b)
    counts = (d != 0).sum(axis=1).astype(np.uint32)
    return d, counts, chunk_digests_ref(c, chunk_words)
