"""Host-facing wrapper for the fused chunk pre-codec pass.

``fused_precodec`` takes the current and base snapshots as flat uint32
word streams (the serialized-tree byte stream viewed as words) and runs
the fused kernel once over the whole state: one launch, one HBM sweep,
emitting per-chunk XOR deltas plus a ``(changed, S, T)`` meta row per
chunk.  ``CheckpointConfig.chunk_size`` must be a multiple of
``CHUNK_ALIGN`` (4096 bytes — one native ``(8, 128)`` uint32 tile) so
chunks tile exactly.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import interpret_default
from repro.kernels.fused.fused import TILE, fused_chunk_tiles

CHUNK_ALIGN = TILE * 4  # bytes per native tile; chunk_size must be a multiple


@partial(jax.jit, static_argnames=("chunk_words", "interpret"))
def fused_precodec(cur, base, *, chunk_words: int, interpret=None):
    """Fused delta + dirty-count + checksum over chunked word streams.

    ``cur``/``base``: equal-length 1-D uint32 arrays (zero-pad is
    applied here up to a chunk multiple; zero padding is neutral for
    both the dirty count and the checksum tracks).  Returns
    ``(delta, meta)`` with ``delta`` shaped ``(n_chunks, chunk_words)``
    uint32 and ``meta`` shaped ``(n_chunks, 3)`` uint32 rows of
    ``(changed_words, S, T)``.
    """
    if interpret is None:
        interpret = interpret_default()
    if chunk_words <= 0 or chunk_words % TILE:
        raise ValueError(
            f"chunk_words must be a positive multiple of {TILE}, got {chunk_words}"
        )
    c = jnp.asarray(cur, dtype=jnp.uint32).reshape(-1)
    b = jnp.asarray(base, dtype=jnp.uint32).reshape(-1)
    if c.shape != b.shape:
        raise ValueError(f"stream length mismatch: {c.shape} vs {b.shape}")
    rem = (-c.size) % chunk_words
    if rem:
        c = jnp.pad(c, (0, rem))
        b = jnp.pad(b, (0, rem))
    tiles_per_chunk = chunk_words // TILE
    n_chunks = c.size // chunk_words
    ct = c.reshape(n_chunks, tiles_per_chunk, 8, 128)
    bt = b.reshape(n_chunks, tiles_per_chunk, 8, 128)
    delta, meta = fused_chunk_tiles(ct, bt, interpret=interpret)
    return delta.reshape(n_chunks, chunk_words), meta


def digests_from_meta(meta: np.ndarray) -> np.ndarray:
    """(n_chunks, 3) uint32 meta rows -> (n_chunks,) uint64 digests."""
    m = np.asarray(meta, dtype=np.uint64)
    return (m[:, 2] << np.uint64(32)) | m[:, 1]


def dirty_from_meta(meta: np.ndarray) -> np.ndarray:
    """(n_chunks, 3) uint32 meta rows -> (n_chunks,) bool dirty mask."""
    return np.asarray(meta)[:, 0] > 0
