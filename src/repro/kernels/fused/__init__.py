from repro.kernels.fused.fused import TILE, fused_chunk_tiles
from repro.kernels.fused.ops import (
    CHUNK_ALIGN,
    digests_from_meta,
    dirty_from_meta,
    fused_precodec,
)
from repro.kernels.fused.ref import chunk_digests_ref, fused_ref

__all__ = [
    "CHUNK_ALIGN",
    "TILE",
    "chunk_digests_ref",
    "digests_from_meta",
    "dirty_from_meta",
    "fused_chunk_tiles",
    "fused_precodec",
    "fused_ref",
]
