"""Pallas TPU kernels for checkpoint-path compute hot-spots.

The paper optimizes checkpoint I/O; the on-device compute that feeds the
flush pipeline (integrity checksums, lossy int8 compression, XOR deltas
for incremental checkpoints) is implemented here as TPU kernels with
explicit VMEM BlockSpecs, validated on CPU in interpret mode against the
pure-numpy/jnp oracles in each ``ref.py``.
"""
from repro.kernels.checksum import checksum_u32, digest_array, digest_bytes
from repro.kernels.delta import xor_delta
from repro.kernels.fused import (
    digests_from_meta,
    dirty_from_meta,
    fused_precodec,
)
from repro.kernels.quantize import dequantize, quantize

__all__ = [
    "checksum_u32",
    "digest_array",
    "digest_bytes",
    "digests_from_meta",
    "dirty_from_meta",
    "fused_precodec",
    "xor_delta",
    "quantize",
    "dequantize",
]
