"""jit'd wrapper around the checksum kernel + cross-tile combine."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import bytes_to_u32, interpret_default
from repro.kernels.checksum.checksum import TILE, TILE_COLS, TILE_ROWS, checksum_tiles
from repro.kernels.checksum.ref import IDX_MOD


@partial(jax.jit, static_argnames=("interpret",))
def checksum_u32(words: jnp.ndarray, *, interpret: bool | None = None) -> jnp.ndarray:
    """Two-track checksum of a 1-D uint32 array -> (2,) uint32 = (S, T).

    Zero-padding to a tile multiple is checksum-neutral for S and T
    (padded words are 0).
    """
    if interpret is None:
        interpret = interpret_default()
    w = words.astype(jnp.uint32).reshape(-1)
    if w.shape[0] == 0:
        return jnp.zeros((2,), jnp.uint32)
    pad = (-w.shape[0]) % TILE
    if pad:
        w = jnp.pad(w, (0, pad))
    n_tiles = w.shape[0] // TILE
    tiles = w.reshape(n_tiles, TILE_ROWS, TILE_COLS)
    partials = checksum_tiles(tiles, interpret=interpret)  # (n_tiles, 2)
    s_g = partials[:, 0]
    t_g = partials[:, 1]
    base = (jnp.arange(n_tiles, dtype=jnp.uint32) * jnp.uint32(TILE)) % jnp.uint32(
        IDX_MOD
    )
    s = jnp.sum(s_g, dtype=jnp.uint32)
    t = jnp.sum(t_g + base * s_g, dtype=jnp.uint32)
    return jnp.stack([s, t])


def digest_bytes(data: bytes, *, interpret: bool | None = None) -> int:
    """Host entry: digest of a byte string via the device kernel."""
    words = jnp.asarray(bytes_to_u32(data))
    s, t = np.asarray(checksum_u32(words, interpret=interpret))
    return (int(t) << 32) | int(s)


def _as_u32(x: jax.Array) -> jax.Array:
    x = x.reshape(-1)
    isz = x.dtype.itemsize
    if isz == 4:
        return jax.lax.bitcast_convert_type(x, jnp.uint32)
    if isz < 4:
        per = 4 // isz
        pad = (-x.shape[0]) % per
        if pad:
            x = jnp.pad(x, (0, pad))
        return jax.lax.bitcast_convert_type(x.reshape(-1, per), jnp.uint32).reshape(-1)
    # 8-byte dtypes -> (n, 2) u32 limbs
    return jax.lax.bitcast_convert_type(x, jnp.uint32).reshape(-1)


def digest_array(x: jax.Array, *, interpret: bool | None = None) -> jax.Array:
    """Digest of an on-device array (pre-D2H integrity for the flush path)."""
    return checksum_u32(_as_u32(x), interpret=interpret)
