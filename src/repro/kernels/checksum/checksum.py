"""Pallas TPU kernel: two-track chunked checksum over uint32 words.

Layout: the word stream is reshaped to ``(n_tiles, 8, 128)`` — one
``(8, 128)`` uint32 tile per grid step, the native VREG-aligned 32-bit
tile shape.  Each grid step reduces its tile to a partial
``(S_tile, T_tile)`` pair; the cheap cross-tile combine happens in
``ops.py`` (the global position weight of tile ``g`` is ``g * TILE %
IDX_MOD``, folded in after the fact).

All arithmetic is uint32 with natural wrap-around (mod 2^32), identical
to the numpy oracle in ``ref.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_ROWS = 8
TILE_COLS = 128
TILE = TILE_ROWS * TILE_COLS  # 1024 words per grid step


def _checksum_kernel(w_ref, out_ref):
    w = w_ref[0]  # (8, 128) uint32 tile in VMEM
    # local position index 0..TILE-1 (row-major), exact in uint32
    rows = jax.lax.broadcasted_iota(jnp.uint32, (TILE_ROWS, TILE_COLS), 0)
    cols = jax.lax.broadcasted_iota(jnp.uint32, (TILE_ROWS, TILE_COLS), 1)
    idx = rows * jnp.uint32(TILE_COLS) + cols
    s = jnp.sum(w, dtype=jnp.uint32)
    t = jnp.sum(idx * w, dtype=jnp.uint32)
    out_ref[0, 0] = s
    out_ref[0, 1] = t


def checksum_tiles(words: jnp.ndarray, *, interpret: bool) -> jnp.ndarray:
    """words: (n_tiles, 8, 128) uint32 -> (n_tiles, 2) uint32 partials."""
    n_tiles = words.shape[0]
    return pl.pallas_call(
        _checksum_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((1, TILE_ROWS, TILE_COLS), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 2), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles, 2), jnp.uint32),
        interpret=interpret,
    )(words)
