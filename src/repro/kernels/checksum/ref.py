"""Pure-jnp/numpy oracle for the two-track chunked checksum.

Definition (TPU-adapted Fletcher-64 style — see DESIGN.md §3): over
uint32 words ``w_i``, with all arithmetic mod 2^32 (natural unsigned
wrap; end-around carry mod 2^32-1 has no efficient vectorized form on
the TPU VPU):

    S = sum_i w_i                    (content track)
    T = sum_i (i mod 2^20) * w_i     (position track)

The digest is ``(T << 32) | S``.  Any single-bit flip changes S; any
swap/move of words changes T.  The position index is reduced mod 2^20 so
the per-tile index weights are exact in uint32 for tiles up to 2^12
words (products < 2^32 never lose information before the deliberate
wrap-around accumulation).
"""
from __future__ import annotations

import numpy as np

IDX_MOD = 1 << 20


def checksum_ref_np(words: np.ndarray) -> tuple[int, int]:
    w = np.ascontiguousarray(words, dtype=np.uint32)
    idx = (np.arange(w.size, dtype=np.uint64) % IDX_MOD).astype(np.uint32)
    s = int(np.add.reduce(w, dtype=np.uint64) & 0xFFFFFFFF)
    # exact products in uint64, wrap the accumulation to 32 bits
    t = int((np.multiply(idx.astype(np.uint64), w.astype(np.uint64))).sum() & 0xFFFFFFFF)
    return s, t


def digest_ref(words: np.ndarray) -> int:
    s, t = checksum_ref_np(words)
    return (t << 32) | s
