from repro.kernels.checksum.ops import checksum_u32, digest_array, digest_bytes
from repro.kernels.checksum.ref import checksum_ref_np, digest_ref

__all__ = ["checksum_u32", "digest_array", "digest_bytes", "checksum_ref_np", "digest_ref"]
