"""Pure-jnp oracle for blockwise int8 quantization.

Block size = 128 values (one VPU lane row).  Per block: symmetric
absmax scaling,

    scale = max(|x|) / 127          (scale 0 -> block of zeros)
    q     = round_half_away(x / scale)  clipped to [-127, 127]
    x'    = q * scale

Round-half-away-from-zero (not banker's rounding) so the kernel and the
oracle agree bit-exactly on ties across backends.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

BLOCK = 128


def quantize_ref(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """x: (n_blocks, BLOCK) float -> (q int8 same shape, scales (n_blocks,) f32)."""
    xf = np.asarray(x, dtype=np.float32)
    absmax = np.abs(xf).max(axis=-1)
    scale = absmax / 127.0
    safe = np.where(scale > 0, scale, 1.0)[:, None]
    q = np.trunc(xf / safe + np.where(xf >= 0, 0.5, -0.5))
    q = np.clip(q, -127, 127).astype(np.int8)
    q = np.where(scale[:, None] > 0, q, 0).astype(np.int8)
    return q, scale.astype(np.float32)


def dequantize_ref(q: np.ndarray, scale: np.ndarray) -> np.ndarray:
    return (q.astype(np.float32) * np.asarray(scale, np.float32)[:, None]).astype(
        np.float32
    )


def quantize_ref_jnp(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    absmax = jnp.max(jnp.abs(xf), axis=-1)
    scale = absmax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)[:, None]
    q = jnp.trunc(xf / safe + jnp.where(xf >= 0, 0.5, -0.5))
    q = jnp.clip(q, -127, 127)
    q = jnp.where(scale[:, None] > 0, q, 0).astype(jnp.int8)
    return q, scale.astype(jnp.float32)
