from repro.kernels.quantize.ops import dequantize, quantize, quantize_blocks_needed
from repro.kernels.quantize.ref import dequantize_ref, quantize_ref, quantize_ref_jnp

__all__ = [
    "quantize",
    "dequantize",
    "quantize_blocks_needed",
    "quantize_ref",
    "dequantize_ref",
    "quantize_ref_jnp",
]
