"""Pallas TPU kernels: blockwise int8 quantize / dequantize.

Tile shape ``(32, 128)`` — the native int8 VMEM tile — so each grid step
quantizes 32 blocks of 128 values.  Scales live in a ``(32, 1)`` f32
sliver per tile (8-bit data + 32-bit scales never share a tile).  The
fused quantize kernel computes absmax, scale, and rounded/clipped int8
in one VMEM pass — this runs over every checkpointed tensor on the
lossy flush tier, ahead of D2H, so HBM traffic is the roofline term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 32     # blocks per tile
BLOCK = 128   # values per quantization block (lane dim)


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[0].astype(jnp.float32)             # (32, 128)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = absmax / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    q = jnp.trunc(x / safe + jnp.where(x >= 0, 0.5, -0.5))
    q = jnp.clip(q, -127.0, 127.0)
    q = jnp.where(scale > 0, q, 0.0)
    q_ref[0] = q.astype(jnp.int8)
    s_ref[0] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[0].astype(jnp.float32)             # (32, 128)
    s = s_ref[0]                                  # (32, 1)
    x_ref[0] = q * s


def quantize_tiles(x: jnp.ndarray, *, interpret: bool):
    """x: (n_tiles, 32, 128) float -> (q int8 same shape, scales (n_tiles,32,1) f32)."""
    n = x.shape[0]
    return pl.pallas_call(
        _quant_kernel,
        grid=(n,),
        in_specs=[pl.BlockSpec((1, ROWS, BLOCK), lambda g: (g, 0, 0))],
        out_specs=[
            pl.BlockSpec((1, ROWS, BLOCK), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, ROWS, 1), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, ROWS, BLOCK), jnp.int8),
            jax.ShapeDtypeStruct((n, ROWS, 1), jnp.float32),
        ],
        interpret=interpret,
    )(x)


def dequantize_tiles(q: jnp.ndarray, s: jnp.ndarray, *, interpret: bool):
    n = q.shape[0]
    return pl.pallas_call(
        _dequant_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, ROWS, BLOCK), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, ROWS, 1), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, ROWS, BLOCK), lambda g: (g, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, ROWS, BLOCK), jnp.float32),
        interpret=interpret,
    )(q, s)
