"""jit'd wrappers: flatten / pad / tile, call the kernels, un-tile."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.kernels.quantize.quantize import BLOCK, ROWS, dequantize_tiles, quantize_tiles

TILE = ROWS * BLOCK


@partial(jax.jit, static_argnames=("interpret",))
def quantize(x: jax.Array, *, interpret: bool | None = None) -> Tuple[jax.Array, jax.Array]:
    """Any-shape float array -> (q int8 (n_blocks, 128), scales (n_blocks,) f32).

    Flattens, zero-pads to a tile multiple; padding blocks quantize to
    zero scale and are dropped by :func:`dequantize` (which knows the
    original size).
    """
    if interpret is None:
        interpret = interpret_default()
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % TILE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    tiles = flat.reshape(-1, ROWS, BLOCK)
    q, s = quantize_tiles(tiles, interpret=interpret)
    return q.reshape(-1, BLOCK), s.reshape(-1)


@partial(jax.jit, static_argnames=("n", "interpret"))
def dequantize(
    q: jax.Array, s: jax.Array, *, n: int, interpret: bool | None = None
) -> jax.Array:
    """(q, scales) -> flat f32 array of length ``n`` (original element count)."""
    if interpret is None:
        interpret = interpret_default()
    tiles = q.reshape(-1, ROWS, BLOCK)
    sc = s.reshape(-1, ROWS, 1)
    x = dequantize_tiles(tiles, sc, interpret=interpret)
    return x.reshape(-1)[:n]


def quantize_blocks_needed(n: int) -> int:
    padded = n + ((-n) % TILE)
    return padded // BLOCK
