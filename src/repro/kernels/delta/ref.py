"""Oracle for the XOR-delta kernel (incremental checkpoints).

delta = cur XOR prev (uint32 words); the per-tile count of changed
words is the side output driving the engine's "is this delta worth
compressing?" decision.
"""
from __future__ import annotations

import numpy as np


def delta_ref(cur: np.ndarray, prev: np.ndarray) -> tuple[np.ndarray, int]:
    c = np.ascontiguousarray(cur, np.uint32)
    p = np.ascontiguousarray(prev, np.uint32)
    d = np.bitwise_xor(c, p)
    return d, int(np.count_nonzero(d))
