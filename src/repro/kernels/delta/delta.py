"""Pallas TPU kernel: fused XOR-delta + changed-word count.

One pass over both streams in ``(8, 128)`` uint32 tiles: emits the XOR
delta and a per-tile changed-word count (int32).  Fusing the count into
the delta pass saves a second HBM sweep — at checkpoint sizes (GBs) the
kernel is purely HBM-bandwidth-bound, so one pass instead of two halves
the cost of incremental checkpointing's encode step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
COLS = 128
TILE = ROWS * COLS


def _delta_kernel(c_ref, p_ref, d_ref, n_ref):
    c = c_ref[0]
    p = p_ref[0]
    d = jnp.bitwise_xor(c, p)
    d_ref[0] = d
    n_ref[0, 0] = jnp.sum((d != 0).astype(jnp.int32))


def delta_tiles(cur: jnp.ndarray, prev: jnp.ndarray, *, interpret: bool):
    """(n_tiles, 8, 128) u32 x2 -> (delta same shape, counts (n_tiles, 1) i32)."""
    n = cur.shape[0]
    return pl.pallas_call(
        _delta_kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, ROWS, COLS), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, ROWS, COLS), lambda g: (g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ROWS, COLS), lambda g: (g, 0, 0)),
            pl.BlockSpec((1, 1), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, ROWS, COLS), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(cur, prev)
