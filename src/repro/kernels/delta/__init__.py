from repro.kernels.delta.ops import xor_delta
from repro.kernels.delta.ref import delta_ref

__all__ = ["xor_delta", "delta_ref"]
