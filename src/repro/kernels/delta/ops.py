"""jit'd wrapper for the XOR-delta kernel."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.kernels.delta.delta import COLS, ROWS, TILE, delta_tiles


@partial(jax.jit, static_argnames=("interpret",))
def xor_delta(
    cur: jax.Array, prev: jax.Array, *, interpret: bool | None = None
) -> Tuple[jax.Array, jax.Array]:
    """uint32 streams -> (delta uint32 same length, changed word count int32)."""
    if interpret is None:
        interpret = interpret_default()
    c = cur.reshape(-1).astype(jnp.uint32)
    p = prev.reshape(-1).astype(jnp.uint32)
    if c.shape != p.shape:
        raise ValueError("delta requires equal-length streams")
    n = c.shape[0]
    pad = (-n) % TILE
    if pad:
        c = jnp.pad(c, (0, pad))
        p = jnp.pad(p, (0, pad))
    ct = c.reshape(-1, ROWS, COLS)
    pt = p.reshape(-1, ROWS, COLS)
    d, counts = delta_tiles(ct, pt, interpret=interpret)
    return d.reshape(-1)[:n], jnp.sum(counts)
