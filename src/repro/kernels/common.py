"""Shared helpers for the Pallas TPU kernels.

All kernels target TPU (pl.pallas_call + explicit BlockSpec VMEM tiling)
and are validated on CPU in interpret mode: ``interpret_default()`` turns
interpretation on automatically when no TPU is present, so the same
``ops.py`` entry points run everywhere.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def pad_to_multiple(x: jnp.ndarray, multiple: int, axis: int = 0, value=0):
    n = x.shape[axis]
    pad = (-n) % multiple
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value), n


def bytes_to_u32(data: bytes) -> np.ndarray:
    buf = np.frombuffer(data, dtype=np.uint8)
    pad = (-buf.size) % 4
    if pad:
        buf = np.concatenate([buf, np.zeros(pad, dtype=np.uint8)])
    return buf.view(np.uint32)
