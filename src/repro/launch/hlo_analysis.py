"""Trip-count-corrected cost extraction from partitioned HLO text.

XLA's ``HloCostAnalysis`` (behind ``compiled.cost_analysis()``) visits
every computation once — a ``lax.scan`` body (while loop) is counted a
single time regardless of trip count, so scanned-layer models report
~L× too few FLOPs.  This module re-derives costs from ``as_text()``:

1. split the module into computations;
2. build the call graph (``body=``/``condition=``/``calls=``/``to_apply=``);
3. recover each while's trip count from the integer constant in its
   condition computation (jax lowers scan to ``i < trip``);
4. multiplier(comp) = Σ over call sites of multiplier(caller) x trip;
5. FLOPs: ``dot``/``convolution`` ops — 2 x |result| x contraction size
   (elementwise flops are ignored: matmul-dominated modules, documented);
6. HBM-traffic proxy: Σ (result + operand bytes) over instructions at
   fusion boundaries (parameters/tuples/gtes/bitcasts/copies excluded)
   — pessimistic for TPU (CPU fusions are smaller), documented;
7. collective bytes by op kind, trip-corrected.

All shapes in partitioned HLO are per-partition => every number here is
per-device.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALL_ATTR_RE = re.compile(r"(?:body|condition|calls|to_apply)=%([\w.\-]+)")
_WHILE_RE = re.compile(r"while\(.*\), condition=%([\w.\-]+), body=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SKIP_OPS = (
    "parameter(", "get-tuple-element(", "tuple(", "constant(", "bitcast(",
    "after-all(", "partition-id(", "replica-id(", "iota(",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    result_bytes: Dict[str, int] = field(default_factory=dict)
    result_dims: Dict[str, List[int]] = field(default_factory=dict)


def split_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", line)
            if m:
                cur = Computation(name=m.group(1))
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        cur.lines.append(line)
        d = _DEF_RE.match(line)
        if d:
            name = d.group(1)
            head = d.group(2).split("(", 1)[0]
            cur.result_bytes[name] = _shape_bytes(head)
            shapes = _shape_dims(head)
            cur.result_dims[name] = shapes[0][1] if len(shapes) == 1 else []
    return comps


def _while_trip(cond: Computation) -> int:
    """Trip bound = the max integer constant in the condition body."""
    best = 1
    for line in cond.lines:
        for c in _CONST_RE.findall(line):
            best = max(best, int(c))
    return best


def compute_multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    """multiplier(comp) = Σ_callsites multiplier(caller) * weight."""
    entry = None
    called = set()
    calls: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    for cname, comp in comps.items():
        for line in comp.lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond_name, body_name = wm.group(1), wm.group(2)
                trip = _while_trip(comps[cond_name]) if cond_name in comps else 1
                if body_name in comps:
                    calls[body_name].append((cname, float(trip)))
                    called.add(body_name)
                if cond_name in comps:
                    calls[cond_name].append((cname, float(trip)))
                    called.add(cond_name)
                continue
            for callee in _CALL_ATTR_RE.findall(line):
                if callee in comps:
                    calls[callee].append((cname, 1.0))
                    called.add(callee)
    roots = [c for c in comps if c not in called]
    mult: Dict[str, float] = {}

    def visit(c: str, seen) -> float:
        if c in mult:
            return mult[c]
        if c in seen:  # recursion guard (shouldn't happen in HLO)
            return 1.0
        seen = seen | {c}
        if c in [r for r in roots]:
            mult[c] = 1.0
            return 1.0
        total = 0.0
        for caller, w in calls[c]:
            total += visit(caller, seen) * w
        mult[c] = total if total > 0 else 1.0
        return mult[c]

    for c in comps:
        visit(c, frozenset())
    return mult


def _dot_flops(comp: Computation, line: str) -> float:
    d = _DEF_RE.match(line)
    if not d:
        return 0.0
    body = d.group(2)
    head = body.split("(", 1)[0]
    result_shapes = _shape_dims(head)
    if not result_shapes:
        return 0.0
    result_elems = math.prod(result_shapes[0][1]) if result_shapes[0][1] else 1
    # contraction size from lhs operand + lhs_contracting_dims
    ops = _OPERAND_RE.findall(body.split("(", 1)[1].split(")", 1)[0])
    lhs_dims = comp.result_dims.get(ops[0], []) if ops else []
    m = re.search(r"lhs_contracting_dims={([\d,]*)}", line)
    contract = 1
    if m and lhs_dims:
        for idx in m.group(1).split(","):
            if idx != "" and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * result_elems * contract


@dataclass
class HloCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collectives: Dict[str, float] = field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())


def analyze_hlo(hlo: str) -> HloCost:
    comps = split_computations(hlo)
    mult = compute_multipliers(comps)
    cost = HloCost(collectives={k: 0.0 for k in _COLL_KINDS})
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        for line in comp.lines:
            d = _DEF_RE.match(line)
            if not d:
                continue
            body = d.group(2)
            if " dot(" in line or body.startswith("dot("):
                cost.flops += m * _dot_flops(comp, line)
            if "while(" in body:
                cost.n_while += 1
            for kind in _COLL_KINDS:
                if re.search(r"\b" + kind + r"(-start)?\(", body):
                    b = comp.result_bytes.get(d.group(1), 0)
                    if kind + "-start(" in body:
                        b //= 2  # start tuples carry (input, output)
                    if "-done(" not in body:
                        cost.collectives[kind] += m * b
                    break
            # HBM traffic proxy at fusion boundaries
            if not any(s in body for s in _SKIP_OPS):
                rb = comp.result_bytes.get(d.group(1), 0)
                ob = 0
                inner = body.split("(", 1)[1].split(")", 1)[0] if "(" in body else ""
                for op in _OPERAND_RE.findall(inner):
                    ob += comp.result_bytes.get(op, 0)
                cost.traffic_bytes += m * (rb + ob)
    for c in comps.values():
        pass
    cost.max_trip = int(max([_while_trip(c) for c in comps.values()] + [1]))
    return cost
