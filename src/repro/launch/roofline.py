"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI.  ``cost_analysis()`` of an SPMD-partitioned module is
per-partition (verified empirically), so:

    compute term    = flops_per_device / peak_flops
    memory term     = bytes_accessed_per_device / hbm_bw
    collective term = collective_bytes_per_device / link_bw

``collective_bytes`` sums the *result-shape* bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in the
partitioned HLO (per-partition shapes).  Result-shape is a deliberate,
documented proxy: it equals bytes-on-the-wire per device for ring
all-gather and collective-permute, and undercounts all-reduce by ~2x —
the breakdown per op type is reported so that can be seen.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / ICI link
DCN_BW = 6.25e9          # B/s / chip inter-pod (25 GbE x2 per host / 4 chips)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_KINDS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"\s(" + "|".join(_COLL_KINDS) + r")(-start)?\(")


def _result_bytes(line: str) -> int:
    """Sum shape bytes on the lhs of `%name = <shapes> op(...)`."""
    head = line.split("(", 1)[0]
    if " = " in head:
        head = head.split(" = ", 1)[1]
    total = 0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-partition collective bytes by op kind (result-shape proxy).

    Async pairs: the ``-done`` op aliases the ``-start`` result, so only
    ``-start`` (and synchronous forms) are counted.
    """
    out: Dict[str, int] = {k: 0 for k in _COLL_KINDS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m or " = " not in line:
            continue
        kind = m.group(1)
        nbytes = _result_bytes(line)
        if m.group(2):  # -start results carry (input, output, ...) tuples
            nbytes //= 2
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    return out


@dataclass
class RooflineTerms:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    n_chips: int
    model_flops_global: float
    coll_breakdown: Dict[str, int] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        return self.flops_per_dev / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_dev / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled HLO flops (remat/redundancy waste)."""
        hlo_global = self.flops_per_dev * self.n_chips
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the machine at the modeled bound:
        (MODEL_FLOPS / peak) / max(term)."""
        if self.bound_s <= 0:
            return 0.0
        ideal_s = self.model_flops_global / (self.n_chips * PEAK_FLOPS)
        return ideal_s / self.bound_s

    def row(self) -> Dict[str, object]:
        return {
            "flops_per_dev": self.flops_per_dev,
            "bytes_per_dev": self.bytes_per_dev,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "coll_breakdown": {
                k: v for k, v in self.coll_breakdown.items() if v
            },
        }


def model_flops(kind: str, n_params_active: int, global_batch: int, seq_len: int) -> float:
    """6·N·D for training, 2·N·D forward-only; decode processes B tokens."""
    if kind == "train":
        return 6.0 * n_params_active * global_batch * seq_len
    if kind == "prefill":
        return 2.0 * n_params_active * global_batch * seq_len
    if kind == "decode":
        return 2.0 * n_params_active * global_batch  # one new token per seq
    raise ValueError(kind)
