"""Production mesh builders.

Functions, not module-level constants: importing this module never
touches jax device state (the dry-run must set XLA_FLAGS before any
device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 dual-pod (512 chips).

    Axes: ``data`` carries FSDP + data parallelism, ``model`` carries
    tensor/expert parallelism, ``pod`` (multi-pod only) is an outer
    data-parallel axis whose collectives ride the inter-pod DCN.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this process actually has (CPU smoke runs): 1x1 mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
