import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (test-suite may shrink the placeholder device pool; production stays 512)
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

No arrays are ever allocated: inputs are ShapeDtypeStructs, the product
is the compiled executable's memory/cost analysis + the partitioned HLO,
from which EXPERIMENTS.md's §Dry-run and §Roofline tables are built.

    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k                      # one cell, single-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Results are cached under reports/dryrun/ as JSON; --force recompiles.
"""
import argparse
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, cell_applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import (
    DCN_BW, LINK_BW, RooflineTerms, collective_bytes, model_flops,
)
from repro.models import get_model
from repro.models.sharding import (
    activation_sharding,
    batch_specs,
    cache_specs,
    fsdp_axes,
    param_specs,
    _maybe,
)
from repro.train import OptConfig, TrainConfig, init_train_state, train_state_specs
from repro.train.train_step import batch_spec_tree, build_train_step

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"

# per-arch dry-run knobs: grad-microbatching + optimizer/accum dtypes keep
# the big dense models inside v5e HBM (see EXPERIMENTS.md §Dry-run)
# microbatch counts tuned in the §Perf loop: fewer microbatches = fewer
# per-pass FSDP weight re-gathers (the dominant collective everywhere),
# bounded by activation-stack memory (sequence-parallel residuals).
KNOBS: Dict[str, Dict[str, Any]] = {
    "llama3-405b": dict(microbatches=4, opt_dtype="bfloat16", accum="bfloat16"),
    "qwen2-72b": dict(microbatches=4, opt_dtype="bfloat16", accum="bfloat16"),
    "llama4-scout-17b-a16e": dict(microbatches=2, opt_dtype="bfloat16", accum="bfloat16"),
    "llava-next-mistral-7b": dict(microbatches=2, opt_dtype="bfloat16", accum="float32"),
    "qwen2-moe-a2.7b": dict(microbatches=4, opt_dtype=None, accum="float32"),
    "recurrentgemma-2b": dict(microbatches=4, opt_dtype=None, accum="float32"),
    "whisper-small": dict(microbatches=4, opt_dtype=None, accum="float32"),
    "xlstm-350m": dict(microbatches=4, opt_dtype=None, accum="float32"),
}
DEFAULT_KNOBS = dict(microbatches=2, opt_dtype=None, accum="float32")


def _ns(mesh: Mesh, tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def analytic_hbm_bytes(model, cell, mesh, knobs) -> float:
    """Per-device HBM traffic floor (B/step) from first principles.

    The measured HLO traffic proxy is pessimistic on the CPU backend
    (small fusions); this analytic floor brackets it from below and is
    used as the §Roofline memory term:

    * weights are FSDP-gathered then read once per pass (fwd / remat-fwd
      / bwd for train; once for inference) at 1/TP residency;
    * optimizer update streams params + both moments (read+write);
    * boundary activations: one write + one read per remat checkpoint;
    * decode reads the local KV-cache shard once and appends once.
    """
    import math as _m

    cfg = model.cfg
    n_dev = mesh.devices.size
    tp = int(mesh.shape["model"])
    fs = n_dev // tp
    struct = model.param_struct()
    param_bytes = sum(
        _m.prod(l.shape) * l.dtype.itemsize
        for l in jax.tree_util.tree_leaves(struct)
    )
    p_tp = param_bytes / tp          # post-gather residency
    p_loc = param_bytes / n_dev      # FSDP-sharded residency
    tokens_dev = cell.global_batch * cell.seq_len / max(1, fs)
    act_dt = 2  # bf16 activations
    d = cfg.d_model
    L = cfg.n_layers + cfg.n_enc_layers
    if cell.kind == "train":
        k = int(knobs["microbatches"])
        opt_itemsize = 2 if knobs["opt_dtype"] == "bfloat16" else (
            jnp.dtype(cfg.param_dtype).itemsize
        )
        weights = k * 3.0 * p_tp                    # fwd + remat-fwd + bwd
        grads = 4.0 * p_loc                          # accumulate rd+wr (x2)
        opt = 6.0 * (param_bytes * opt_itemsize / jnp.dtype(cfg.param_dtype).itemsize) / n_dev + 2.0 * p_loc
        acts = 2.0 * L * tokens_dev * d * act_dt     # ckpt write + bwd read
        logits = 4.0 * tokens_dev * cfg.vocab_size / tp * act_dt
        return weights + grads + opt + acts + logits
    if cell.kind == "prefill":
        kv_dim = cfg.n_kv_heads * cfg.hd
        cache = 2.0 * L * tokens_dev * kv_dim * act_dt      # write k+v
        # chunked attention re-reads K/V per query chunk
        n_chunks = max(1, cell.seq_len // 1024)
        kv_reread = 2.0 * L * n_chunks * (cell.seq_len * kv_dim * act_dt) * (
            cell.global_batch / max(1, fs)
        )
        acts = 2.0 * L * tokens_dev * d * act_dt
        return p_tp + cache + kv_reread + acts
    # decode: weights once + cache shard read + append
    if cfg.family in ("ssm",):
        cache = 0.0  # O(1) recurrent state
    elif cfg.family == "hybrid":
        win = cfg.window or 2048
        n_attn = sum(1 for kk in cfg.layer_kinds() if kk == "attn")
        cache = (
            n_attn * cell.global_batch * win * cfg.n_kv_heads * cfg.hd * act_dt * 2
        ) / max(1, fs)
    else:
        s_kv = min(cell.seq_len, 2 ** 31)
        cache = (
            2.0 * L * cell.global_batch * s_kv * cfg.n_kv_heads * cfg.hd * act_dt
        ) / max(1, fs)
    return p_tp + cache


def lower_cell(arch: str, shape_name: str, mesh: Mesh, *, donate: bool = True):
    """Returns (lowered, meta) for the cell, or raises."""
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    model = get_model(cfg)
    knobs = KNOBS.get(arch, DEFAULT_KNOBS)
    meta: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "knobs": {k: str(v) for k, v in knobs.items()},
    }

    if cell.kind == "train":
        tcfg = TrainConfig(
            opt=OptConfig(state_dtype=knobs["opt_dtype"]),
            microbatches=knobs["microbatches"],
            accum_dtype=knobs["accum"],
        )
        batch_struct = model.batch_struct(cell.global_batch, cell.seq_len)
        state_struct = jax.eval_shape(
            lambda: init_train_state(model, jax.random.PRNGKey(0), tcfg)
        )
        sspecs = train_state_specs(model, mesh, tcfg)
        bspecs = batch_spec_tree(model, mesh, batch_struct)
        mspec = {"loss": P(), "grad_norm": P(), "lr": P()}
        with activation_sharding(mesh):
            fn = jax.jit(
                build_train_step(model, tcfg),
                in_shardings=(_ns(mesh, sspecs), _ns(mesh, bspecs)),
                out_shardings=(_ns(mesh, sspecs), _ns(mesh, mspec)),
                donate_argnums=(0,) if donate else (),
            )
            lowered = fn.lower(state_struct, batch_struct)
        return lowered, meta

    pspecs = param_specs(model, mesh)
    param_struct = model.param_struct()

    if cell.kind == "prefill":
        batch_struct = model.batch_struct(cell.global_batch, cell.seq_len)
        bspecs = batch_spec_tree(model, mesh, batch_struct)
        with activation_sharding(mesh):
            fn = jax.jit(
                lambda params, batch: model.prefill(params, batch, s_max=cell.seq_len),
                in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)),
            )
            lowered = fn.lower(param_struct, batch_struct)
        return lowered, meta

    if cell.kind == "decode":
        cache_struct = model.cache_struct(cell.global_batch, cell.seq_len)
        cspecs = cache_specs(model, mesh, cell.global_batch, cell.seq_len)
        F = fsdp_axes(mesh)
        tok_spec = P(_maybe(cell.global_batch, F, mesh), None)
        tok_struct = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
        with activation_sharding(mesh):
            fn = jax.jit(
                lambda params, cache, tok: model.decode_step(params, cache, tok),
                in_shardings=(
                    _ns(mesh, pspecs), _ns(mesh, cspecs), NamedSharding(mesh, tok_spec)
                ),
                donate_argnums=(1,) if donate else (),
            )
            lowered = fn.lower(param_struct, cache_struct, tok_struct)
        return lowered, meta

    raise ValueError(cell.kind)


def run_cell(
    arch: str, shape_name: str, *, multi_pod: bool, force: bool = False,
    mesh: Optional[Mesh] = None, report_dir: Optional[Path] = None,
) -> Dict[str, Any]:
    rdir = report_dir or REPORT_DIR
    rdir.mkdir(parents=True, exist_ok=True)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    out_path = rdir / f"{arch}__{shape_name}__{mesh_tag}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, cell)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "kind": cell.kind, "status": "skip" if not ok else "pending",
    }
    if not ok:
        rec["skip_reason"] = why
        out_path.write_text(json.dumps(rec, indent=1))
        return rec

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.perf_counter()
    try:
        lowered, meta = lower_cell(arch, shape_name, mesh)
        t_lower = time.perf_counter() - t0
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t1
        ca = compiled.cost_analysis() or {}
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        # trip-count-corrected per-device costs (XLA counts scan bodies
        # once; analyze_hlo multiplies by recovered while trip counts)
        hc = analyze_hlo(hlo)
        model = get_model(cfg)
        n_active = model.param_count(active_only=True)
        mf = model_flops(cell.kind, n_active, cell.global_batch, cell.seq_len)
        knobs = KNOBS.get(arch, DEFAULT_KNOBS)
        mem_floor = analytic_hbm_bytes(model, cell, mesh, knobs)
        terms = RooflineTerms(
            flops_per_dev=float(hc.flops),
            bytes_per_dev=float(mem_floor),
            coll_bytes_per_dev=float(hc.collective_total),
            n_chips=n_chips,
            model_flops_global=mf,
            coll_breakdown={k: int(v) for k, v in hc.collectives.items()},
        )
        rec.update(meta)
        rec.update(
            {
                "status": "ok",
                "n_chips": n_chips,
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "memory": {
                    "argument_bytes": ma.argument_size_in_bytes,
                    "output_bytes": ma.output_size_in_bytes,
                    "temp_bytes": ma.temp_size_in_bytes,
                    "alias_bytes": ma.alias_size_in_bytes,
                    "peak_bytes": getattr(ma, "peak_memory_in_bytes", 0),
                    # live-per-device = args + temps (aliased args reused)
                    "per_device_bytes": ma.argument_size_in_bytes
                    + ma.temp_size_in_bytes
                    + ma.output_size_in_bytes
                    - ma.alias_size_in_bytes,
                },
                "cost": {k: v for k, v in ca.items() if isinstance(v, (int, float))},
                "cost_corrected": {
                    "flops_per_dev": hc.flops,
                    "traffic_bytes_per_dev": hc.traffic_bytes,
                    "n_while": hc.n_while,
                    "max_trip": hc.max_trip,
                },
                "roofline": terms.row(),
                "n_params_active": n_active,
            }
        )
    except Exception as e:  # record the failure; the harness keeps going
        rec.update(
            {
                "status": "error",
                "error": repr(e),
                "traceback": traceback.format_exc()[-4000:],
            }
        )
    out_path.write_text(json.dumps(rec, indent=1, default=str))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    archs = args.arch or (list(ARCHS) if args.all else ["tinyllama-1.1b"])
    shapes = args.shape or list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    n_bad = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            for shape in shapes:
                rec = run_cell(
                    arch, shape, multi_pod=multi_pod, force=args.force, mesh=mesh
                )
                status = rec["status"]
                if status == "ok":
                    r = rec["roofline"]
                    mem = rec["memory"]["per_device_bytes"] / 2**30
                    print(
                        f"[{rec['mesh']}] {arch:24s} {shape:12s} OK  "
                        f"compile {rec['compile_s']:7.1f}s  mem/dev {mem:6.2f} GiB  "
                        f"dom={r['dominant']:10s} "
                        f"terms(c/m/n)=({r['compute_s']:.3f}/{r['memory_s']:.3f}/"
                        f"{r['collective_s']:.3f})s  roofline_frac={r['roofline_fraction']:.3f}"
                    )
                elif status == "skip":
                    print(f"[{rec['mesh']}] {arch:24s} {shape:12s} SKIP ({rec['skip_reason'][:60]})")
                else:
                    n_bad += 1
                    print(f"[{rec['mesh']}] {arch:24s} {shape:12s} ERROR {rec['error'][:120]}")
    return 1 if n_bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
