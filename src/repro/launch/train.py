"""End-to-end training driver with aggregated async checkpointing.

Example (CPU smoke scale):

    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 30 --ckpt-every 10 --strategy stripe_aligned \
        --root /tmp/ckpt_demo --nodes 4 --ppn 2

Restart resumes from the deepest complete checkpoint level, including
optimizer moments and the data-pipeline cursor (bit-exact batch replay).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core import CheckpointConfig, CheckpointManager, theta_like
from repro.data import DataConfig, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import get_model
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


def build_argparser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    # checkpointing
    ap.add_argument("--root", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--strategy", default="stripe_aligned",
                    choices=["file_per_process", "posix", "mpiio",
                             "stripe_aligned", "gio_sync"])
    ap.add_argument("--codec", default="none",
                    choices=["none", "zstd", "zstd+delta"])
    ap.add_argument("--precodec", default="none", choices=["none", "int8"])
    ap.add_argument("--io-threads", type=int, default=2)
    ap.add_argument("--nodes", type=int, default=4)
    ap.add_argument("--ppn", type=int, default=2)
    ap.add_argument("--keep", type=int, default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--partner-replication", action="store_true")
    return ap


def main(argv=None) -> int:
    args = build_argparser().parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = get_model(cfg)
    mesh = make_host_mesh()

    data = SyntheticTokens(
        DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq_len,
            global_batch=args.global_batch,
            n_patches=cfg.n_patches,
            enc_seq=cfg.enc_seq if cfg.family == "audio" else 0,
            d_model=cfg.d_model,
            family=cfg.family,
        )
    )
    tcfg = TrainConfig(
        opt=OptConfig(lr=args.lr, total_steps=args.steps),
        microbatches=args.microbatches,
    )
    batch_struct = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), data.peek(0)
    )
    step_fn, state_specs, _ = make_train_step(model, tcfg, mesh, batch_struct)

    def place_state(st):
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
            st, state_specs,
            is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
        )

    cluster = theta_like(args.nodes, args.ppn)
    mgr = CheckpointManager(
        CheckpointConfig(
            root=args.root,
            cluster=cluster,
            strategy=args.strategy,
            codec=args.codec,
            precodec=args.precodec,
            io_threads=args.io_threads,
            keep_n=args.keep,
            partner_replication=args.partner_replication,
        )
    )

    state = place_state(init_train_state(model, jax.random.PRNGKey(0), tcfg))
    full_state = {"train": state, "data": data.state_tree()}
    start = 0
    if args.resume:
        try:
            target = jax.tree_util.tree_map(np.asarray, full_state)
            step, restored = mgr.restore(target)
            state = place_state(jax.tree_util.tree_map(jnp.asarray, restored["train"]))
            data.load_state(restored["data"])
            start = int(state["step"])
            print(f"[resume] restored step {step} (train step {start})")
        except FileNotFoundError:
            print("[resume] no checkpoint found; cold start")

    t_step_accum = 0.0
    for i in range(start, args.steps):
        batch = data.next()
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        t_step_accum += dt
        if (i + 1) % args.ckpt_every == 0 or (i + 1) == args.steps:
            st = mgr.save(i + 1, {"train": state, "data": data.state_tree()})
            print(
                f"step {i+1:5d} loss {loss:.4f} step_time {dt*1e3:7.1f} ms  "
                f"[ckpt local {st.local_time*1e3:.1f} ms, "
                f"{st.raw_bytes/1e6:.1f} MB raw -> {st.stored_bytes/1e6:.1f} MB]"
            )
        else:
            print(f"step {i+1:5d} loss {loss:.4f} step_time {dt*1e3:7.1f} ms")
    mgr.wait()
    if mgr.flush_errors:
        print("flush errors:", mgr.flush_errors)
        return 1
    flushes = [s for s in mgr.stats if s.flush is not None]
    if flushes:
        tot = sum(f.flush.bytes_written for f in flushes)
        dur = sum(f.flush.duration for f in flushes)
        print(
            f"[ckpt] {len(flushes)} flushes, {tot/1e6:.1f} MB, "
            f"avg flush {dur/len(flushes)*1e3:.1f} ms, "
            f"blocking overhead {sum(f.local_time for f in flushes)*1e3:.1f} ms "
            f"vs compute {t_step_accum*1e3:.1f} ms"
        )
    mgr.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
