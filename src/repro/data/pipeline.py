"""Deterministic, checkpointable synthetic token pipeline.

The stream is a position-keyed PRNG draw over a Markov-ish structure so
the LM loss actually decreases (structure to learn), while batch ``i``
is a pure function of ``(seed, i)`` — restart from a checkpointed
``state`` reproduces the exact upcoming batches (tested), and sharding
by data-parallel rank is trivial (each host slices its batch rows).

The pipeline state is a tiny pytree ``{"batch_idx": int32}`` that rides
inside the train checkpoint, which is how the paper's system guarantees
bit-exact resume of the *whole* training job, not just the weights.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # vlm / audio frontends (stub embeddings)
    n_patches: int = 0
    enc_seq: int = 0
    d_model: int = 0
    family: str = "dense"


class SyntheticTokens:
    def __init__(self, cfg: DataConfig, state: Optional[Dict[str, Any]] = None):
        self.cfg = cfg
        self.state: Dict[str, Any] = state or {"batch_idx": jnp.zeros((), jnp.int32)}

    # -- deterministic batch as a function of (seed, idx) -------------------
    def _batch_at(self, idx: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), idx)
        k1, k2, k3 = jax.random.split(key, 3)
        v = cfg.vocab_size
        # structured stream: blocks of arithmetic runs + noise tokens
        base = jax.random.randint(k1, (cfg.global_batch, 1), 0, v)
        step = jax.random.randint(k2, (cfg.global_batch, 1), 1, 7)
        pos = jnp.arange(cfg.seq_len)[None, :]
        runs = (base + step * pos) % v
        noise = jax.random.randint(k3, runs.shape, 0, v)
        keep = (pos % 17) != 0
        tokens = jnp.where(keep, runs, noise).astype(jnp.int32)
        batch: Dict[str, Any] = {"tokens": tokens}
        if cfg.family == "vlm" and cfg.n_patches:
            batch["patches"] = jax.random.normal(
                k3, (cfg.global_batch, cfg.n_patches, cfg.d_model), jnp.float32
            )
        if cfg.family == "audio" and cfg.enc_seq:
            batch["frames"] = jax.random.normal(
                k3, (cfg.global_batch, cfg.enc_seq, cfg.d_model), jnp.float32
            )
        return batch

    def next(self) -> Dict[str, np.ndarray]:
        idx = int(self.state["batch_idx"])
        batch = self._batch_at(idx)
        self.state = {"batch_idx": jnp.asarray(idx + 1, jnp.int32)}
        return batch

    def peek(self, idx: int) -> Dict[str, np.ndarray]:
        return self._batch_at(idx)

    # state rides inside the training checkpoint
    def state_tree(self) -> Dict[str, Any]:
        return dict(self.state)

    def load_state(self, state: Dict[str, Any]) -> None:
        self.state = {"batch_idx": jnp.asarray(state["batch_idx"], jnp.int32)}
