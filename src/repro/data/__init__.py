from repro.data.pipeline import DataConfig, SyntheticTokens

__all__ = ["DataConfig", "SyntheticTokens"]
