"""Pytree helpers: named leaves, byte accounting.

Kept dependency-light (jax.tree_util only) so the checkpoint core can use
them without importing model code.
"""
from __future__ import annotations

from typing import Any, List, Tuple

import jax
import numpy as np
from jax.tree_util import tree_flatten_with_path, keystr


def leaf_paths(tree: Any) -> List[str]:
    """Stable, human-readable path string per leaf (manifest keys)."""
    leaves, _ = tree_flatten_with_path(tree)
    return [keystr(path) for path, _ in leaves]


def flatten_with_names(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves, treedef = tree_flatten_with_path(tree)
    return [(keystr(path), leaf) for path, leaf in leaves], treedef


def _leaf_nbytes(x: Any) -> int:
    if hasattr(x, "nbytes"):
        return int(x.nbytes)
    if isinstance(x, (int, float, bool)):
        return 8
    return len(np.asarray(x).tobytes())


def tree_bytes(tree: Any) -> int:
    """Total serialized payload size of a pytree (array leaves only)."""
    return sum(_leaf_nbytes(l) for l in jax.tree_util.tree_leaves(tree))
