"""Human-readable formatting helpers used across logs / benchmarks."""
from __future__ import annotations


def fmt_bytes(n: float) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB", "TiB", "PiB"):
        if abs(n) < 1024.0 or unit == "PiB":
            return f"{n:.2f} {unit}" if unit != "B" else f"{int(n)} B"
        n /= 1024.0
    return f"{n:.2f} PiB"


def fmt_dur(seconds: float) -> str:
    if seconds < 1e-6:
        return f"{seconds * 1e9:.1f} ns"
    if seconds < 1e-3:
        return f"{seconds * 1e6:.1f} us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f} ms"
    if seconds < 120.0:
        return f"{seconds:.2f} s"
    return f"{seconds / 60.0:.1f} min"


def fmt_bw(bytes_per_sec: float) -> str:
    return f"{bytes_per_sec / 1e9:.2f} GB/s"
