from repro.utils.humanize import fmt_bytes, fmt_dur, fmt_bw
from repro.utils.treelib import leaf_paths, tree_bytes, flatten_with_names

__all__ = [
    "fmt_bytes",
    "fmt_dur",
    "fmt_bw",
    "leaf_paths",
    "tree_bytes",
    "flatten_with_names",
]
