"""xLSTM LM (arXiv:2405.04517): alternating mLSTM and sLSTM blocks.

* mLSTM — matrix-memory cell with exponential gating.  Training uses the
  stabilized *parallel* (quadratic, attention-like) form; decoding uses
  the O(1)-per-token recurrent form with carried (C, n, m) state — this
  is what makes the ``long_500k`` shape cell tractable.
* sLSTM — scalar-memory cell with hidden-state recurrence (inherently
  sequential): ``lax.scan`` over time for training, one step for decode.

Blocks follow the paper's structure: pre-norm, up-projection (factor 2)
with causal conv4 + SiLU on the q/k path, gated output, down-projection.
Layer kinds alternate per ``cfg.block_pattern`` (default 3x mLSTM : 1x
sLSTM); layers are a python list (kinds differ), not a scanned stack.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import dense_init, embed_init, rms_norm
from repro.models.sharding import shard_act


def _conv_causal(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x (B,S,D), w (W,D) -> (B,S,D)."""
    wlen = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(wlen):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[wlen - 1 - i].astype(x.dtype)
    return out + b.astype(x.dtype)


def _conv_step(x_t, conv_state, w, b):
    """x_t (B,D); conv_state (B,W-1,D) holds previous inputs (oldest first)."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # (B,W,D)
    out = jnp.einsum("bwd,wd->bd", window, w.astype(x_t.dtype)) + b.astype(x_t.dtype)
    return out, window[:, 1:]


def _headnorm(h: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    """RMS-norm each head's output (GroupNorm analogue). h: (...,H,hd)."""
    hf = h.astype(jnp.float32)
    var = jnp.mean(hf * hf, axis=-1, keepdims=True)
    out = hf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(h.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(rng, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    di = 2 * d
    h = cfg.n_heads
    ks = jax.random.split(rng, 10)
    return {
        "ln": jnp.zeros((d,), cfg.pdt),
        "w_up": dense_init(ks[0], (d, di), cfg.pdt),
        "w_z": dense_init(ks[1], (d, di), cfg.pdt),
        "conv_w": dense_init(ks[2], (cfg.conv_width, di), cfg.pdt, scale=0.3),
        "conv_b": jnp.zeros((di,), cfg.pdt),
        "wq": dense_init(ks[3], (di, di), cfg.pdt),
        "wk": dense_init(ks[4], (di, di), cfg.pdt),
        "wv": dense_init(ks[5], (di, di), cfg.pdt),
        "w_i": dense_init(ks[6], (di, h), cfg.pdt),
        "w_f": dense_init(ks[7], (di, h), cfg.pdt),
        "b_i": jnp.zeros((h,), cfg.pdt),
        "b_f": jnp.full((h,), 3.0, cfg.pdt),  # open forget gates at init
        "gn": jnp.zeros((h, di // h), cfg.pdt),
        "w_down": dense_init(ks[8], (di, d), cfg.pdt),
    }


def _mlstm_parallel(q, k, v, logf, logi):
    """q/k/v: (B,S,H,hd); logf/logi: (B,S,H) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    f32 = jnp.float32
    F = jnp.cumsum(logf.astype(f32), axis=1)                   # (B,S,H)
    D = (
        F.transpose(0, 2, 1)[:, :, :, None]                     # F_i
        - F.transpose(0, 2, 1)[:, :, None, :]                   # F_j
        + logi.astype(f32).transpose(0, 2, 1)[:, :, None, :]    # + logi_j
    )                                                           # (B,H,S,S)
    mask = jnp.tril(jnp.ones((s, s), bool))
    D = jnp.where(mask[None, None], D, -jnp.inf)
    m = jnp.max(D, axis=-1, keepdims=True)                      # (B,H,S,1)
    m = jnp.maximum(m, -1e30)  # guard all -inf rows
    w = jnp.exp(D - m)
    scores = jnp.einsum(
        "bihd,bjhd->bhij", q.astype(f32), k.astype(f32)
    ) * (hd ** -0.5) * w
    denom = jnp.maximum(jnp.abs(scores.sum(-1, keepdims=True)), jnp.exp(-m))
    out = jnp.einsum("bhij,bjhd->bihd", scores / denom, v.astype(f32))
    return out.astype(q.dtype)




def _mlstm_chunked(q, k, v, logf, logi, chunk: int):
    """Chunkwise-parallel mLSTM (the xLSTM paper's training form).

    Within a chunk: the stabilized quadratic form.  Across chunks: the
    exact recurrent state (C, n, m) carries — O(S*c) memory instead of
    O(S^2), and the final carry IS the decode state.

    Returns (h (B,S,H,hd), (C, n, m) after the last token).
    """
    b, s, h, hd = q.shape
    f32 = jnp.float32
    n_ch = s // chunk
    assert s % chunk == 0

    def to_chunks(x):
        return x.reshape(b, n_ch, chunk, *x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    lfc, lic = to_chunks(logf.astype(f32)), to_chunks(logi.astype(f32))

    C0 = jnp.zeros((b, h, hd, hd), f32)
    n0 = jnp.zeros((b, h, hd), f32)
    m0 = jnp.full((b, h), -1e30, f32)

    def step(carry, xs):
        C, n, m = carry
        qi, ki, vi, lf, li = xs                       # (b,c,h,*)
        qi, ki, vi = qi.astype(f32), ki.astype(f32), vi.astype(f32)
        L = jnp.cumsum(lf, axis=1)                    # (b,c,h) inclusive
        Lh = L.transpose(0, 2, 1)                     # (b,h,c)
        lih = li.transpose(0, 2, 1)
        D = Lh[:, :, :, None] - Lh[:, :, None, :] + lih[:, :, None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(mask[None, None], D, -jnp.inf)
        m_intra = jnp.maximum(jnp.max(D, axis=-1), -1e30)       # (b,h,c)
        m_tot = jnp.maximum(m_intra, Lh + m[:, :, None])        # (b,h,c)
        w = jnp.exp(D - m_tot[..., None])
        scores = jnp.einsum("bihd,bjhd->bhij", qi, ki) * (hd ** -0.5) * w
        carry_w = jnp.exp(Lh + m[:, :, None] - m_tot)           # (b,h,c)
        qC = jnp.einsum("bihd,bhde->bhie", qi, C)               # (b,h,c,hd)
        numer = jnp.einsum("bhij,bjhd->bhid", scores, vi) + carry_w[..., None] * qC
        dsum = scores.sum(-1) + carry_w * jnp.einsum("bihd,bhd->bhi", qi, n)
        denom = jnp.maximum(jnp.abs(dsum), jnp.exp(-m_tot))
        h_out = (numer / denom[..., None]).transpose(0, 2, 1, 3)  # (b,c,h,hd)

        Lc = Lh[:, :, -1]                                        # (b,h)
        e_j = Lc[:, :, None] - Lh + lih                          # (b,h,c)
        m_end = jnp.max(e_j, axis=-1)
        m_new = jnp.maximum(Lc + m, m_end)
        wj = jnp.exp(e_j - m_new[:, :, None])                    # (b,h,c)
        k_sc = ki * (hd ** -0.5)
        decay = jnp.exp(Lc + m - m_new)
        C_new = decay[..., None, None] * C + jnp.einsum(
            "bhj,bjhd,bjhe->bhde", wj, k_sc, vi
        )
        n_new = decay[..., None] * n + jnp.einsum("bhj,bjhd->bhd", wj, k_sc)
        return (C_new, n_new, m_new), h_out

    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, lfc, lic))
    h_full = hs.swapaxes(0, 1).reshape(b, s, h, hd).astype(q.dtype)
    return h_full, (C, n, m)


def _mlstm_apply(q, k, v, logf, logi, *, chunk: int = 1024):
    s = q.shape[1]
    c = chunk if (s > chunk and s % chunk == 0) else s
    return _mlstm_chunked(q, k, v, logf, logi, c)


def _mlstm_qkv(p, cfg: ModelConfig, x):
    dt = x.dtype
    h = cfg.n_heads
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    xm = shard_act(jnp.einsum("bsd,de->bse", xn, p["w_up"].astype(dt)), "dp", None, "tp")
    z = shard_act(jnp.einsum("bsd,de->bse", xn, p["w_z"].astype(dt)), "dp", None, "tp")
    xc = jax.nn.silu(
        _conv_causal(xm, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    ).astype(dt)
    di = xm.shape[-1]
    hd = di // h
    b, s = x.shape[0], x.shape[1]
    # sequence-parallel mLSTM: queries (and the quadratic D matrix's i
    # dim) shard over "tp"; k/v stay batch-sharded and are broadcast.
    q = shard_act(
        jnp.einsum("bse,ef->bsf", xc, p["wq"].astype(dt)).reshape(b, s, h, hd),
        "dp", "tp", None, None,
    )
    k = jnp.einsum("bse,ef->bsf", xc, p["wk"].astype(dt)).reshape(b, s, h, hd)
    v = jnp.einsum("bse,ef->bsf", xm, p["wv"].astype(dt)).reshape(b, s, h, hd)
    logi = jnp.einsum("bse,eh->bsh", xc, p["w_i"].astype(dt)) + p["b_i"].astype(dt)
    logf = jax.nn.log_sigmoid(
        (jnp.einsum("bse,eh->bsh", xc, p["w_f"].astype(dt)) + p["b_f"].astype(dt))
        .astype(jnp.float32)
    )
    return q, k, v, logi.astype(jnp.float32), logf, z


def mlstm_block(p, cfg: ModelConfig, x):
    q, k, v, logi, logf, z = _mlstm_qkv(p, cfg, x)
    hout, _ = _mlstm_apply(q, k, v, logf, logi)
    hout = _headnorm(hout, p["gn"], cfg.norm_eps)
    b, s = x.shape[0], x.shape[1]
    flat = hout.reshape(b, s, -1) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    flat = shard_act(flat, "dp", None, "tp")
    out = x + jnp.einsum("bse,ed->bsd", flat, p["w_down"].astype(x.dtype))
    return shard_act(out, "dp", None, None)


def mlstm_decode(p, cfg: ModelConfig, state, x_t):
    """x_t: (B,1,d); state: {C (B,H,hd,hd), n (B,H,hd), m (B,H), conv (B,W-1,di)}."""
    dt = x_t.dtype
    h = cfg.n_heads
    xn = rms_norm(x_t[:, 0], p["ln"], cfg.norm_eps)
    xm = jnp.einsum("bd,de->be", xn, p["w_up"].astype(dt))
    z = jnp.einsum("bd,de->be", xn, p["w_z"].astype(dt))
    conv_out, conv_state = _conv_step(xm, state["conv"], p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(conv_out.astype(jnp.float32)).astype(dt)
    di = xm.shape[-1]
    hd = di // h
    b = x_t.shape[0]
    q = jnp.einsum("be,ef->bf", xc, p["wq"].astype(dt)).reshape(b, h, hd)
    k = jnp.einsum("be,ef->bf", xc, p["wk"].astype(dt)).reshape(b, h, hd)
    v = jnp.einsum("be,ef->bf", xm, p["wv"].astype(dt)).reshape(b, h, hd)
    logi = (
        jnp.einsum("be,eh->bh", xc, p["w_i"].astype(dt)) + p["b_i"].astype(dt)
    ).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(
        (jnp.einsum("be,eh->bh", xc, p["w_f"].astype(dt)) + p["b_f"].astype(dt))
        .astype(jnp.float32)
    )
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m, logi)                          # (B,H)
    a = jnp.exp(logf + m - m_new)[..., None]
    bgate = jnp.exp(logi - m_new)[..., None]
    kf = k.astype(jnp.float32) * (hd ** -0.5)
    vf = v.astype(jnp.float32)
    C = a[..., None] * C + bgate[..., None] * jnp.einsum("bhd,bhe->bhde", kf, vf)
    n = a * n + bgate * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhde,bhd->bhe", C, qf)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf))[..., None], jnp.exp(-m_new)[..., None]
    )
    hout = (num / den).astype(dt)                                # (B,H,hd)
    hout = _headnorm(hout, p["gn"], cfg.norm_eps)
    flat = hout.reshape(b, -1) * jax.nn.silu(z.astype(jnp.float32)).astype(dt)
    y = x_t[:, 0] + jnp.einsum("be,ed->bd", flat, p["w_down"].astype(dt))
    return {"C": C, "n": n, "m": m_new, "conv": conv_state}, y[:, None]


def mlstm_state(cfg: ModelConfig, b: int) -> Dict[str, jnp.ndarray]:
    di = 2 * cfg.d_model
    h = cfg.n_heads
    hd = di // h
    f32 = jnp.float32
    return {
        "C": jnp.zeros((b, h, hd, hd), f32),
        "n": jnp.zeros((b, h, hd), f32),
        "m": jnp.full((b, h), -1e30, f32),
        "conv": jnp.zeros((b, cfg.conv_width - 1, di), cfg.cdt),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(rng, cfg: ModelConfig) -> Dict[str, Any]:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(rng, 10)
    return {
        "ln": jnp.zeros((d,), cfg.pdt),
        "w": dense_init(ks[0], (d, 4 * d), cfg.pdt),            # z,i,f,o inputs
        "r": dense_init(ks[1], (h, hd, 4 * hd), cfg.pdt, scale=0.4),  # recurrent (block-diag)
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,), cfg.pdt), jnp.full((d,), 3.0, cfg.pdt), jnp.zeros((d,), cfg.pdt)]
        ),
        "gn": jnp.zeros((h, hd), cfg.pdt),
        "w_down": dense_init(ks[2], (d, d), cfg.pdt),
    }


def _slstm_step(p, cfg: ModelConfig, carry, wx_t):
    """carry: (h, c, n, m) each (B,H,hd); wx_t: (B, 4d) precomputed Wx."""
    hprev, c, n, m = carry
    hcat = hprev  # (B,H,hd)
    rec = jnp.einsum("bhd,hde->bhe", hcat.astype(jnp.float32), p["r"].astype(jnp.float32))
    b, h, _ = hprev.shape
    hd = cfg.d_model // cfg.n_heads
    pre = wx_t.reshape(b, h, 4 * hd).astype(jnp.float32) + rec + p["b"].astype(
        jnp.float32
    ).reshape(h, 4 * hd)[None]
    z_pre, i_pre, f_pre, o_pre = jnp.split(pre, 4, axis=-1)
    z = jnp.tanh(z_pre)
    logi = i_pre
    logf = jax.nn.log_sigmoid(f_pre)
    o = jax.nn.sigmoid(o_pre)
    m_new = jnp.maximum(logf + m, logi)
    c = jnp.exp(logf + m - m_new) * c + jnp.exp(logi - m_new) * z
    n = jnp.exp(logf + m - m_new) * n + jnp.exp(logi - m_new)
    h_new = o * (c / jnp.maximum(n, 1e-6))
    return (h_new, c, n, m_new), h_new


def slstm_block(p, cfg: ModelConfig, x):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    wx = shard_act(jnp.einsum("bsd,de->bse", xn, p["w"].astype(x.dtype)), "dp", None, "tp")
    carry = slstm_state(cfg, b)
    carry = (carry["h"], carry["c"], carry["n"], carry["m"])
    (_, _, _, _), ys = jax.lax.scan(
        lambda cr, wt: _slstm_step(p, cfg, cr, wt), carry, wx.transpose(1, 0, 2)
    )
    ys = ys.transpose(1, 0, 2, 3)                                # (B,S,H,hd)
    ys = _headnorm(ys.astype(x.dtype), p["gn"], cfg.norm_eps)
    out = x + jnp.einsum("bsd,de->bse", ys.reshape(b, s, d), p["w_down"].astype(x.dtype))
    return shard_act(out, "dp", None, None)


def slstm_decode(p, cfg: ModelConfig, state, x_t):
    xn = rms_norm(x_t[:, 0], p["ln"], cfg.norm_eps)
    wx = jnp.einsum("bd,de->be", xn, p["w"].astype(x_t.dtype))
    carry = (state["h"], state["c"], state["n"], state["m"])
    (h_new, c, n, m), y = _slstm_step(p, cfg, carry, wx)
    b, d = x_t.shape[0], cfg.d_model
    ys = _headnorm(y.astype(x_t.dtype), p["gn"], cfg.norm_eps)
    out = x_t[:, 0] + jnp.einsum("bd,de->be", ys.reshape(b, d), p["w_down"].astype(x_t.dtype))
    return {"h": h_new, "c": c, "n": n, "m": m}, out[:, None]


def slstm_state(cfg: ModelConfig, b: int) -> Dict[str, jnp.ndarray]:
    h = cfg.n_heads
    hd = cfg.d_model // h
    f32 = jnp.float32
    return {
        "h": jnp.zeros((b, h, hd), f32),
        "c": jnp.zeros((b, h, hd), f32),
        "n": jnp.full((b, h, hd), 1e-6, f32),
        "m": jnp.full((b, h, hd), -1e30, f32),
    }


# ---------------------------------------------------------------------------
# LM assembly
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    kinds = cfg.layer_kinds()
    ks = jax.random.split(rng, cfg.n_layers + 2)
    blocks: List[Dict[str, Any]] = []
    for i, kind in enumerate(kinds):
        if kind == "mlstm":
            blocks.append(init_mlstm(ks[i], cfg))
        elif kind == "slstm":
            blocks.append(init_slstm(ks[i], cfg))
        else:
            raise ValueError(f"xlstm: unknown block kind {kind!r}")
    return {
        "embed": embed_init(ks[-2], (cfg.vocab_size, cfg.d_model), cfg.pdt),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.pdt),
        "out": dense_init(ks[-1], (cfg.vocab_size, cfg.d_model), cfg.pdt),
        "blocks": blocks,
    }


def forward(params, cfg: ModelConfig, tokens, *, remat: bool = True, **_):
    x = shard_act(params["embed"].astype(cfg.cdt)[tokens], "dp", None, None)
    for kind, p in zip(cfg.layer_kinds(), params["blocks"]):
        fn = mlstm_block if kind == "mlstm" else slstm_block
        if remat:
            fn = jax.checkpoint(fn, static_argnums=(1,))
        x = fn(p, cfg, x)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["out"].astype(cfg.cdt))
    return shard_act(logits, "dp", None, "tp"), jnp.zeros((), jnp.float32)


def lm_loss(params, cfg: ModelConfig, tokens, *, remat: bool = True, **_):
    logits, _ = forward(params, cfg, tokens, remat=remat)
    lf = logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    lse = jax.nn.logsumexp(lf, axis=-1)
    # gold logit via mask+reduce: shards over the TP vocab dim with a
    # scalar psum, where take_along_axis all-gathers the logits tensor
    vocab_iota = jnp.arange(lf.shape[-1], dtype=tgt.dtype)
    gold = jnp.sum(jnp.where(vocab_iota == tgt[..., None], lf, 0.0), axis=-1)
    ce = jnp.mean(lse - gold)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


def init_state(params, cfg: ModelConfig, b: int, s_max: int = 0):
    """Recurrent decode state (the 'cache'): O(1) in sequence length.

    Shapes depend only on cfg (``params`` is accepted for API symmetry
    and may be None — dry-run builds the state struct without weights).
    """
    del params, s_max
    states = []
    for kind in cfg.layer_kinds():
        states.append(mlstm_state(cfg, b) if kind == "mlstm" else slstm_state(cfg, b))
    return {"layers": states, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cfg: ModelConfig, state, tokens):
    x = params["embed"].astype(cfg.cdt)[tokens]  # (B,1,d)
    new_states = []
    for kind, p, st in zip(cfg.layer_kinds(), params["blocks"], state["layers"]):
        fn = mlstm_decode if kind == "mlstm" else slstm_decode
        st2, x = fn(p, cfg, st, x)
        new_states.append(st2)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["out"].astype(cfg.cdt))
    return logits, {"layers": new_states, "pos": state["pos"] + 1}


def mlstm_block_with_state(p, cfg: ModelConfig, x):
    """Chunkwise mLSTM forward whose carried (C, n, m) after the last
    chunk IS the decode state — no token scan, O(S*c) memory."""
    q, k, v, logi, logf, z = _mlstm_qkv(p, cfg, x)
    hout, (C, n, m) = _mlstm_apply(q, k, v, logf, logi)
    hout = _headnorm(hout, p["gn"], cfg.norm_eps)
    b, s = x.shape[0], x.shape[1]
    flat = hout.reshape(b, s, -1) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    flat = shard_act(flat, "dp", None, "tp")
    out = shard_act(
        x + jnp.einsum("bse,ed->bsd", flat, p["w_down"].astype(x.dtype)),
        "dp", None, None,
    )
    dt = x.dtype
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    xm = jnp.einsum("bsd,de->bse", xn, p["w_up"].astype(dt))
    wlen = cfg.conv_width - 1
    tail = xm[:, max(0, s - wlen):]
    if tail.shape[1] < wlen:
        tail = jnp.pad(tail, ((0, 0), (wlen - tail.shape[1], 0), (0, 0)))
    state = {"C": C, "n": n, "m": m, "conv": tail.astype(cfg.cdt)}
    return out, state


def slstm_block_with_state(p, cfg: ModelConfig, x):
    """Time-scanned sLSTM forward returning the final carry (inherently
    sequential cell; the scan is over time within one layer only)."""
    b, s, d = x.shape
    h = cfg.n_heads
    xn = rms_norm(x, p["ln"], cfg.norm_eps)
    wx = shard_act(jnp.einsum("bsd,de->bse", xn, p["w"].astype(x.dtype)), "dp", None, "tp")
    st0 = slstm_state(cfg, b)
    carry = (st0["h"], st0["c"], st0["n"], st0["m"])
    (hf, cf, nf, mf), ys = jax.lax.scan(
        lambda cr, wt: _slstm_step(p, cfg, cr, wt), carry, wx.transpose(1, 0, 2)
    )
    ys = ys.transpose(1, 0, 2, 3)
    ys = _headnorm(ys.astype(x.dtype), p["gn"], cfg.norm_eps)
    out = shard_act(
        x + jnp.einsum("bsd,de->bse", ys.reshape(b, s, d), p["w_down"].astype(x.dtype)),
        "dp", None, None,
    )
    return out, {"h": hf, "c": cf, "n": nf, "m": mf}


def prefill(params, cfg: ModelConfig, tokens, *, s_max: Optional[int] = None, **_):
    """Parallel prefill: forward pass + closed-form final recurrent
    states (mLSTM) / per-layer time scans (sLSTM).

    Replaces the token-by-token decode scan whose per-token weight
    gathers dominated the §Roofline baseline for this arch.
    """
    x = shard_act(params["embed"].astype(cfg.cdt)[tokens], "dp", None, None)
    states = []
    for kind, p in zip(cfg.layer_kinds(), params["blocks"]):
        fn = mlstm_block_with_state if kind == "mlstm" else slstm_block_with_state
        x, st = fn(p, cfg, x)
        states.append(st)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["out"].astype(cfg.cdt))
    return {"layers": states, "pos": jnp.asarray(tokens.shape[1], jnp.int32)}, logits
