"""Decoder-only transformer LM: dense (llama/qwen style), MoE, and VLM.

* pre-RMSNorm, GQA attention with RoPE, SwiGLU MLP (or MoE FFN);
* parameters stacked over layers -> ``lax.scan`` over the layer stack
  (compact HLO, fast compiles, remat-friendly);
* VLM (LLaVA-style): precomputed patch embeddings (stub frontend)
  overwrite the first ``n_patches`` sequence positions; loss masks them.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    KVCache,
    attention,
    cache_update,
    decode_attention,
)
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, embed_init, rms_norm, swiglu
from repro.models.moe import moe_ffn
from repro.models.sharding import shard_act


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_layer(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    d, hd = cfg.d_model, cfg.hd
    q_dim = cfg.n_heads * hd
    kv_dim = cfg.n_kv_heads * hd
    ks = jax.random.split(rng, 12)
    p: Dict[str, jnp.ndarray] = {
        "wq": dense_init(ks[0], (d, q_dim), cfg.pdt),
        "wk": dense_init(ks[1], (d, kv_dim), cfg.pdt),
        "wv": dense_init(ks[2], (d, kv_dim), cfg.pdt),
        "wo": dense_init(ks[3], (q_dim, d), cfg.pdt),
        "ln1": jnp.zeros((d,), cfg.pdt),
        "ln2": jnp.zeros((d,), cfg.pdt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((q_dim,), cfg.pdt)
        p["bk"] = jnp.zeros((kv_dim,), cfg.pdt)
        p["bv"] = jnp.zeros((kv_dim,), cfg.pdt)
    if cfg.moe is not None:
        m = cfg.moe
        e_ff = m.expert_d_ff or cfg.d_ff
        p["moe"] = {
            "router": dense_init(ks[4], (d, m.n_experts), jnp.float32),
            "w_gate": dense_init(ks[5], (m.n_experts, d, e_ff), cfg.pdt),
            "w_up": dense_init(ks[6], (m.n_experts, d, e_ff), cfg.pdt),
            "w_down": dense_init(ks[7], (m.n_experts, e_ff, d), cfg.pdt),
        }
        if m.n_shared:
            sh_ff = m.shared_d_ff or m.n_shared * e_ff
            p["moe"]["shared_gate"] = dense_init(ks[8], (d, sh_ff), cfg.pdt)
            p["moe"]["shared_up"] = dense_init(ks[9], (d, sh_ff), cfg.pdt)
            p["moe"]["shared_down"] = dense_init(ks[10], (sh_ff, d), cfg.pdt)
    else:
        p["w_gate"] = dense_init(ks[4], (d, cfg.d_ff), cfg.pdt)
        p["w_up"] = dense_init(ks[5], (d, cfg.d_ff), cfg.pdt)
        p["w_down"] = dense_init(ks[6], (cfg.d_ff, d), cfg.pdt)
    return p


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k_emb, k_out, k_layers = jax.random.split(rng, 3)
    layers = jax.vmap(lambda r: init_layer(r, cfg))(
        jax.random.split(k_layers, cfg.n_layers)
    )
    params: Dict[str, Any] = {
        "embed": embed_init(k_emb, (cfg.vocab_size, cfg.d_model), cfg.pdt),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.pdt),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["out"] = dense_init(k_out, (cfg.vocab_size, cfg.d_model), cfg.pdt)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _attn(p, cfg: ModelConfig, x, positions, *, chunk_q):
    b, s, d = x.shape
    hd = cfg.hd
    dt = x.dtype
    q = jnp.einsum("bsd,dq->bsq", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dq->bsq", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dq->bsq", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    from repro.models.attention import pad_heads_for_tp

    qp, kp, vp, n_h = pad_heads_for_tp(q, k, v)
    qp = shard_act(qp, "dp", None, "tp", None)
    kp = shard_act(kp, "dp", None, "tp", None)
    vp = shard_act(vp, "dp", None, "tp", None)
    o = attention(qp, kp, vp, causal=True, window=cfg.window, chunk_q=chunk_q)
    o = shard_act(o, "dp", None, "tp", None)[:, :, :n_h]
    out = jnp.einsum(
        "bshd,hdm->bsm", o, p["wo"].astype(dt).reshape(cfg.n_heads, hd, d)
    )
    return shard_act(out, "dp", None, None), k, v


def block(p, cfg: ModelConfig, x, positions, *, chunk_q=1024):
    o, _, _ = _attn(p, cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions, chunk_q=chunk_q)
    x = x + o
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = moe_ffn(h, p["moe"], cfg.moe)
    else:
        f = swiglu(h, p["w_gate"], p["w_up"], p["w_down"])
        aux = jnp.zeros((), jnp.float32)
    return x + f, aux


def embed_inputs(params, cfg: ModelConfig, tokens, patches=None):
    x = shard_act(params["embed"].astype(cfg.cdt)[tokens], "dp", None, None)
    if patches is not None:
        pe = shard_act(patches.astype(cfg.cdt), "dp", None, None)
        x = jnp.concatenate([pe, x], axis=1)
    return x


def forward(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    patches: Optional[jnp.ndarray] = None,
    chunk_q: Optional[int] = -1,
    remat: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (logits (B, S, V), aux loss ())."""
    if chunk_q == -1:
        chunk_q = cfg.chunk_q
    x = embed_inputs(params, cfg, tokens, patches)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]

    # sequence parallelism on the carried residual stream: the remat-
    # saved per-layer stack shards its seq dim over "model", cutting
    # checkpoint memory TP-fold (llama3-405b: 15.75 GiB -> 0.98 GiB per
    # stack) at the cost of per-layer seq re-gathers.  Worth it only for
    # wide models — below d_model 4096 the gathers outweigh the saving.
    sp_axis = "tp" if cfg.d_model >= 4096 else None

    def body(carry, lp):
        h, aux = carry
        h, a = block(lp, cfg, h, positions, chunk_q=chunk_q)
        return (shard_act(h, "dp", sp_axis, None), aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out_w = params.get("out", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, out_w.astype(cfg.cdt))
    return shard_act(logits, "dp", None, "tp"), aux


def lm_loss(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    patches: Optional[jnp.ndarray] = None,
    chunk_q: Optional[int] = -1,
    remat: bool = True,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross-entropy (patch prefix positions excluded)."""
    logits, aux = forward(
        params, cfg, tokens, patches=patches, chunk_q=chunk_q, remat=remat
    )
    n_prefix = 0 if patches is None else patches.shape[1]
    # predict tokens[t+1] from position n_prefix + t
    pred = logits[:, n_prefix : n_prefix + tokens.shape[1] - 1]
    tgt = tokens[:, 1:]
    lf = pred.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    # gold logit via mask+reduce: shards over the TP vocab dim with a
    # scalar psum, where take_along_axis all-gathers the logits tensor
    vocab_iota = jnp.arange(lf.shape[-1], dtype=tgt.dtype)
    gold = jnp.sum(jnp.where(vocab_iota == tgt[..., None], lf, 0.0), axis=-1)
    ce = jnp.mean(lse - gold)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


class DecodeCache(NamedTuple):
    k: jnp.ndarray     # (L, B, S_max, H_kv, hd)
    v: jnp.ndarray
    pos: jnp.ndarray   # () int32


def init_cache(cfg: ModelConfig, b: int, s_max: int) -> DecodeCache:
    return DecodeCache(
        k=jnp.zeros((cfg.n_layers, b, s_max, cfg.n_kv_heads, cfg.hd), cfg.cdt),
        v=jnp.zeros((cfg.n_layers, b, s_max, cfg.n_kv_heads, cfg.hd), cfg.cdt),
        pos=jnp.zeros((), jnp.int32),
    )


def prefill(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    patches: Optional[jnp.ndarray] = None,
    s_max: Optional[int] = None,
    chunk_q: Optional[int] = -1,
) -> Tuple[DecodeCache, jnp.ndarray]:
    """Run the prompt, build the KV cache, return logits of the last token."""
    if chunk_q == -1:
        chunk_q = cfg.chunk_q
    x = embed_inputs(params, cfg, tokens, patches)
    b, s, _ = x.shape
    s_max = max(s_max or s, s)  # cache must hold the whole prompt
    positions = jnp.arange(s)[None, :]
    pad = s_max - s

    def body(h, lp):
        o, k, v = _attn(lp, cfg, rms_norm(h, lp["ln1"], cfg.norm_eps), positions, chunk_q=chunk_q)
        h = h + o
        hh = rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_ffn(hh, lp["moe"], cfg.moe)
        else:
            f = swiglu(hh, lp["w_gate"], lp["w_up"], lp["w_down"])
        k = k.reshape(b, s, cfg.n_kv_heads, cfg.hd)
        v = v.reshape(b, s, cfg.n_kv_heads, cfg.hd)
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # cache layout matches decode cache_specs: head_dim over "model"
        k = shard_act(k.astype(cfg.cdt), "dp", None, None, "tp")
        v = shard_act(v.astype(cfg.cdt), "dp", None, None, "tp")
        return shard_act(h + f, "dp", "tp", None), (k, v)

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out_w = params.get("out", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x[:, -1], out_w.astype(cfg.cdt))
    cache = DecodeCache(k=ks, v=vs, pos=jnp.array(s, jnp.int32))
    return cache, logits


def decode_step(
    params, cfg: ModelConfig, cache: DecodeCache, tokens: jnp.ndarray
) -> Tuple[jnp.ndarray, DecodeCache]:
    """tokens: (B, 1) -> (logits (B, V), updated cache)."""
    b = tokens.shape[0]
    x = params["embed"].astype(cfg.cdt)[tokens]     # (B, 1, d)
    positions = cache.pos + jnp.zeros((1, 1), jnp.int32)

    def body(h, layer):
        lp, kc, vc = layer
        hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
        dt = hn.dtype
        hd = cfg.hd
        q = jnp.einsum("bsd,dq->bsq", hn, lp["wq"].astype(dt))
        k = jnp.einsum("bsd,dq->bsq", hn, lp["wk"].astype(dt))
        v = jnp.einsum("bsd,dq->bsq", hn, lp["wv"].astype(dt))
        if cfg.qkv_bias:
            q = q + lp["bq"].astype(dt)
            k = k + lp["bk"].astype(dt)
            v = v + lp["bv"].astype(dt)
        q = q.reshape(b, 1, cfg.n_heads, hd)
        k = k.reshape(b, 1, cfg.n_kv_heads, hd)
        v = v.reshape(b, 1, cfg.n_kv_heads, hd)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        lc = KVCache(k=kc, v=vc, pos=cache.pos)
        lc = cache_update(lc, k, v)
        o = decode_attention(q, lc, window=cfg.window)
        o = jnp.einsum("bshd,hdm->bsm", o, lp["wo"].astype(dt).reshape(cfg.n_heads, hd, cfg.d_model))
        h = h + o
        hh = rms_norm(h, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_ffn(hh, lp["moe"], cfg.moe)
        else:
            f = swiglu(hh, lp["w_gate"], lp["w_up"], lp["w_down"])
        return h + f, (lc.k, lc.v)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache.k, cache.v))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    out_w = params.get("out", params["embed"])
    logits = jnp.einsum("bd,vd->bv", x[:, 0], out_w.astype(cfg.cdt))
    return logits, DecodeCache(k=ks, v=vs, pos=cache.pos + 1)
