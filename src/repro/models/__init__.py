from repro.models.api import Model, get_model
from repro.models.config import ModelConfig, MoEConfig

__all__ = ["Model", "get_model", "ModelConfig", "MoEConfig"]
