"""Shared neural building blocks (pure jnp, no framework)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(
    x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float
) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions_at(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """Analytic sinusoidal embeddings for arbitrary (traced) positions."""
    dim = jnp.arange(d_model // 2, dtype=jnp.float32)
    inv = jnp.exp(-jnp.log(10000.0) * dim / max(1, d_model // 2 - 1))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoidal_positions(n_pos: int, d_model: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal position embedding table."""
    pos = np.arange(n_pos)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    inv = np.exp(-np.log(10000.0) * dim / max(1, d_model // 2 - 1))
    ang = pos * inv
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1).astype(np.float32)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def _act_ff(h: jnp.ndarray) -> jnp.ndarray:
    """Pin wide MLP intermediates: batch over dp, feature over tp."""
    from repro.models.sharding import shard_act

    kinds = ("dp",) + (None,) * (h.ndim - 2) + ("tp",)
    return shard_act(h, *kinds)


def swiglu(x: jnp.ndarray, w_gate, w_up, w_down) -> jnp.ndarray:
    g = _act_ff(jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype)))
    u = _act_ff(jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype)))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down.astype(x.dtype))


def gelu_mlp(x: jnp.ndarray, w_in, b_in, w_out, b_out) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, w_in.astype(x.dtype)) + b_in.astype(x.dtype)
    h = _act_ff(h)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, w_out.astype(x.dtype)) + b_out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initializers (all take explicit rng)
# ---------------------------------------------------------------------------


def dense_init(rng, shape: Tuple[int, ...], dtype, *, scale: Optional[float] = None):
    fan_in = shape[0] if len(shape) >= 2 else max(1, shape[0])
    if len(shape) >= 2:
        fan_in = int(np.prod(shape[:-1]))
    std = scale if scale is not None else (1.0 / np.sqrt(fan_in))
    return (jax.random.normal(rng, shape, jnp.float32) * std).astype(dtype)


def embed_init(rng, shape, dtype):
    return (jax.random.normal(rng, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_rng, shape, dtype):
    return jnp.zeros(shape, dtype)


def causal_mask(s_q: int, s_k: int, *, q_offset: int = 0) -> jnp.ndarray:
    """(s_q, s_k) boolean mask; True = attend."""
    qi = jnp.arange(s_q)[:, None] + q_offset
    ki = jnp.arange(s_k)[None, :]
    return ki <= qi


def window_mask(s_q: int, s_k: int, window: int, *, q_offset: int = 0) -> jnp.ndarray:
    qi = jnp.arange(s_q)[:, None] + q_offset
    ki = jnp.arange(s_k)[None, :]
    return (ki <= qi) & (ki > qi - window)
