"""Mixture-of-Experts FFN: top-k routing, capacity-bounded scatter dispatch.

Dispatch is gather/scatter-based (not one-hot einsum) so compiled FLOPs
reflect *active* expert compute — the roofline's MODEL_FLOPS/HLO_FLOPs
ratio stays honest.  Experts shard over the "model" mesh axis (expert
parallelism); tokens route per sequence group with capacity
``ceil(S * top_k * capacity_factor / n_experts)``; overflow tokens drop
(standard dropped-token MoE semantics).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import MoEConfig
from repro.models.layers import swiglu
from repro.models.sharding import shard_act


def capacity(moe: MoEConfig, seq: int) -> int:
    c = int(-(-seq * moe.top_k * moe.capacity_factor // moe.n_experts))
    return max(4, min(c, seq))


def moe_ffn(
    x: jnp.ndarray,
    params: Dict[str, jnp.ndarray],
    moe: MoEConfig,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux load-balancing loss ())."""
    b, s, d = x.shape
    e, k = moe.n_experts, moe.top_k
    c = capacity(moe, s)

    router_logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(router_logits, axis=-1)              # (B,S,E) f32
    top_v, sel = jax.lax.top_k(router_logits, k)                # (B,S,K)
    gates = jax.nn.softmax(top_v, axis=-1)                      # renormalized

    # position of each (token, k) slot in its expert's queue
    onehot = jax.nn.one_hot(sel, e, dtype=jnp.int32)            # (B,S,K,E)
    flat = onehot.reshape(b, s * k, e)
    pos = jnp.cumsum(flat, axis=1) - 1                          # (B,S*K,E)
    pos_sel = jnp.sum(pos * flat, axis=-1).reshape(b, s, k)     # (B,S,K)
    keep = (pos_sel < c)                                        # capacity drop
    pos_sel = jnp.clip(pos_sel, 0, c - 1)

    # ---- dispatch: per-sequence scatter into (E, C, d), vmapped over
    # batch so the scatter keeps an explicit batch dim (GSPMD partitions
    # the iota-indexed batch as a parallel dim; flat advanced indexing
    # replicated the whole (B,S,K,d) tensor instead).
    def _dispatch_one(xb, selb, posb, keepb):
        contrib = jnp.where(keepb[..., None], xb[:, None, :], 0).astype(x.dtype)
        buf = jnp.zeros((e, c, d), x.dtype)
        return buf.at[selb, posb].add(contrib)

    buf = jax.vmap(_dispatch_one)(x, sel, pos_sel, keep)        # (B,E,C,d)
    buf = shard_act(buf, "dp", "tp", None, None)  # expert parallelism

    # ---- expert computation (E sharded over "model": expert parallelism)
    h = swiglu_experts(buf, params)                             # (B,E,C,d)
    h = shard_act(h, "dp", "tp", None, None)

    # ---- combine: gather back + gate-weighted sum over k ----
    def _combine_one(hb, selb, posb, wb):
        out_k = hb[selb, posb]                                   # (S,K,d)
        return jnp.einsum("skd,sk->sd", out_k, wb)

    w = (gates * keep).astype(x.dtype)                          # dropped -> 0
    out = jax.vmap(_combine_one)(h, sel, pos_sel, w)

    # ---- shared experts (always on) ----
    if "shared_gate" in params:
        out = out + swiglu(
            x, params["shared_gate"], params["shared_up"], params["shared_down"]
        )

    # ---- auxiliary load-balancing loss (Switch-style) ----
    density = jnp.mean(
        onehot.astype(jnp.float32).sum(axis=2).reshape(b * s, e), axis=0
    )  # routed fraction per expert (sums to k)
    prob_mean = jnp.mean(probs.reshape(b * s, e), axis=0)
    aux = e * jnp.sum(density / k * prob_mean) * moe.router_aux_weight
    return out, aux


def swiglu_experts(buf: jnp.ndarray, params: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """buf: (B, E, C, d); expert weights (E, d, ff) / (E, ff, d)."""
    dt = buf.dtype
    g = shard_act(jnp.einsum("becd,edf->becf", buf, params["w_gate"].astype(dt)),
                  "dp", "tp", None, None)
    u = shard_act(jnp.einsum("becd,edf->becf", buf, params["w_up"].astype(dt)),
                  "dp", "tp", None, None)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dt) * u
    return jnp.einsum("becf,efd->becd", h, params["w_down"].astype(dt))
