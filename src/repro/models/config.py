"""Model configuration dataclasses for every supported architecture family."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # always-on shared experts
    expert_d_ff: int = 0         # per-expert hidden dim
    shared_d_ff: int = 0         # shared-expert hidden dim (0 = expert_d_ff * n_shared)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    # hybrid / ssm layer pattern, repeated to n_layers:
    #   dense/moe: ("attn",) -- implicit
    #   xlstm:     e.g. ("mlstm", "mlstm", "mlstm", "slstm")
    #   griffin:   ("rglru", "rglru", "attn")
    block_pattern: Tuple[str, ...] = ()
    window: Optional[int] = None          # local attention window (None = full)
    conv_width: int = 4                   # temporal conv width (ssm/hybrid)
    # encoder-decoder (whisper)
    encdec: bool = False
    n_enc_layers: int = 0
    enc_seq: int = 1500                   # whisper-small 30s @ 50 Hz
    # vlm
    n_patches: int = 0                    # patch embeddings prepended (stub frontend)
    # attention q-chunking (memory-efficient attention granularity);
    # smaller for archs whose head count does not shard over TP
    chunk_q: int = 1024
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # Sub-quadratic in sequence length?  Gates the long_500k shape cell.
    @property
    def subquadratic(self) -> bool:
        if self.family == "ssm":
            return True
        if self.family == "hybrid":
            return True  # recurrence + windowed attention
        return False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def pdt(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdt(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pattern(self) -> Tuple[str, ...]:
        if self.block_pattern:
            return self.block_pattern
        return ("attn",)

    def layer_kinds(self) -> Tuple[str, ...]:
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
