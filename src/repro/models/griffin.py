"""Griffin / RecurrentGemma (arXiv:2402.19427): RG-LRU + local attention.

Layer pattern 2:1 — two recurrent (RG-LRU) residual blocks per local-
attention (MQA, windowed) block; every layer also carries a GeGLU MLP
residual.  The RG-LRU trains via ``lax.associative_scan`` (parallel
prefix over the diagonal linear recurrence) and decodes with O(1) state;
local attention decodes against a ring-buffer KV cache of window size —
together this is why the arch qualifies for the ``long_500k`` cell.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention, repeat_kv
from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, dense_init, embed_init, rms_norm
from repro.models.sharding import shard_act
from repro.models.xlstm import _conv_causal, _conv_step

_C = 8.0  # RG-LRU gate sharpness constant (Griffin paper)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------


def _rglru_coeffs(p, x, *, weights=None):
    """x: (..., dr) -> (a, b) of the recurrence h = a*h_prev + b.

    ``weights=(w_r, w_i)`` lets callers hoist the (FSDP-gathered) gate
    weights out of a chunk scan so they gather once, not per chunk."""
    f32 = jnp.float32
    w_r, w_i = weights if weights is not None else (p["w_r"], p["w_i"])
    r = jax.nn.sigmoid(
        jnp.einsum("...d,de->...e", x.astype(f32), w_r.astype(f32)) + p["b_r"]
    )
    i = jax.nn.sigmoid(
        jnp.einsum("...d,de->...e", x.astype(f32), w_i.astype(f32)) + p["b_i"]
    )
    log_a = -_C * jax.nn.softplus(p["lam"].astype(f32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-8)) * (i * x.astype(f32))
    return a, b


def _combine(l, r):
    al, bl = l
    ar, br = r
    return al * ar, ar * bl + br


def rglru_scan(p, x, *, chunk: int = 512):
    """x: (B,S,dr) -> (B,S,dr): coefficient-fused chunked scan.

    The gate/coefficient computation runs *inside* the chunk scan —
    computing (r, i, a, b) for the full sequence up front keeps
    ~10 f32 (B,S,dr) tensors live per layer (the 39 GiB/device culprit
    on recurrentgemma train); per-chunk they are (B,512,dr) transients.
    The carried hidden state makes chunking exact.
    """
    s = x.shape[1]
    # Chunking halves peak gate memory but adds per-chunk boundary
    # gathers that cost ~0.5s of collectives at 4k (frac 0.209 -> 0.164,
    # measured) — so the full parallel scan stays the default up to 8k
    # and chunking engages only for longer sequences.
    if s <= max(chunk, 8192) or s % chunk:
        a, b = _rglru_coeffs(p, x)
        _, b_c = jax.lax.associative_scan(_combine, (a, b), axis=1)
        return b_c.astype(x.dtype)  # h_0 = 0 => h_t = b_cumulative

    n_ch = s // chunk
    xc = x.reshape(x.shape[0], n_ch, chunk, -1).swapaxes(0, 1)
    # hoist the gate weights: gathered once here, closed over by the scan
    # body (in-scan einsums re-gathered FSDP shards every chunk)
    w_r = shard_act(p["w_r"], None, "tp")
    w_i = shard_act(p["w_i"], None, "tp")

    def step(h0, xi):
        ai, bi = _rglru_coeffs(p, xi, weights=(w_r, w_i))
        cumA, cumB = jax.lax.associative_scan(_combine, (ai, bi), axis=1)
        h = cumB + cumA * h0[:, None, :]
        return h[:, -1], h.astype(x.dtype)

    zero = jnp.zeros((x.shape[0], x.shape[2]), jnp.float32)
    _, hs = jax.lax.scan(step, zero, xc)
    return hs.swapaxes(0, 1).reshape(x.shape[0], s, -1).astype(x.dtype)


def rglru_step(p, h_prev, x_t):
    a, b = _rglru_coeffs(p, x_t)
    h = a * h_prev + b
    return h, h.astype(x_t.dtype)


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def init_block(rng, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    dr = d  # RecurrentGemma: lru_width == d_model
    hd = cfg.hd
    q_dim = cfg.n_heads * hd
    kv_dim = cfg.n_kv_heads * hd
    ks = jax.random.split(rng, 12)
    p: Dict[str, Any] = {
        "ln1": jnp.zeros((d,), cfg.pdt),
        "ln2": jnp.zeros((d,), cfg.pdt),
        # GeGLU MLP on every layer
        "m_gate": dense_init(ks[0], (d, cfg.d_ff), cfg.pdt),
        "m_up": dense_init(ks[1], (d, cfg.d_ff), cfg.pdt),
        "m_down": dense_init(ks[2], (cfg.d_ff, d), cfg.pdt),
    }
    if kind == "rglru":
        p.update(
            {
                "w_x": dense_init(ks[3], (d, dr), cfg.pdt),
                "w_gate": dense_init(ks[4], (d, dr), cfg.pdt),
                "conv_w": dense_init(ks[5], (cfg.conv_width, dr), cfg.pdt, scale=0.3),
                "conv_b": jnp.zeros((dr,), cfg.pdt),
                "w_r": dense_init(ks[6], (dr, dr), jnp.float32, scale=0.02),
                "b_r": jnp.zeros((dr,), jnp.float32),
                "w_i": dense_init(ks[7], (dr, dr), jnp.float32, scale=0.02),
                "b_i": jnp.zeros((dr,), jnp.float32),
                "lam": jnp.full((dr,), 0.65, jnp.float32),
                "w_out": dense_init(ks[8], (dr, d), cfg.pdt),
            }
        )
    elif kind == "attn":
        p.update(
            {
                "wq": dense_init(ks[3], (d, q_dim), cfg.pdt),
                "wk": dense_init(ks[4], (d, kv_dim), cfg.pdt),
                "wv": dense_init(ks[5], (d, kv_dim), cfg.pdt),
                "wo": dense_init(ks[6], (q_dim, d), cfg.pdt),
            }
        )
    else:
        raise ValueError(kind)
    return p


def _mlp(p, cfg: ModelConfig, x):
    h = rms_norm(x, p["ln2"], cfg.norm_eps)
    dt = h.dtype
    kinds = ("dp",) + (None,) * (h.ndim - 2) + ("tp",)
    g = shard_act(jnp.einsum("...d,df->...f", h, p["m_gate"].astype(dt)), *kinds)
    u = shard_act(jnp.einsum("...d,df->...f", h, p["m_up"].astype(dt)), *kinds)
    z = jax.nn.gelu(g.astype(jnp.float32), approximate=True).astype(dt) * u
    return x + jnp.einsum("...f,fd->...d", z, p["m_down"].astype(dt))


def rglru_block(p, cfg: ModelConfig, x):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    dt = h.dtype
    xr = shard_act(jnp.einsum("bsd,de->bse", h, p["w_x"].astype(dt)), "dp", None, "tp")
    gate = jax.nn.gelu(
        jnp.einsum("bsd,de->bse", h, p["w_gate"].astype(dt)).astype(jnp.float32),
        approximate=True,
    ).astype(dt)
    xr = _conv_causal(xr, p["conv_w"], p["conv_b"])
    y = shard_act(rglru_scan(p, xr), "dp", None, "tp")
    x = shard_act(
        x + jnp.einsum("bse,ed->bsd", y * gate, p["w_out"].astype(dt)),
        "dp", None, None,
    )
    return _mlp(p, cfg, x)


def attn_block(p, cfg: ModelConfig, x, positions):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    dt = h.dtype
    b, s, d = h.shape
    hd = cfg.hd
    q = jnp.einsum("bsd,dq->bsq", h, p["wq"].astype(dt)).reshape(b, s, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dq->bsq", h, p["wk"].astype(dt)).reshape(b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dq->bsq", h, p["wv"].astype(dt)).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    # 10 heads pad to 16 so TP shards them (padded heads sliced off)
    from repro.models.attention import pad_heads_for_tp

    qp, kp, vp, n_h = pad_heads_for_tp(q, k, v)
    qp = shard_act(qp, "dp", None, "tp", None)
    o = attention(qp, kp, vp, causal=True, window=cfg.window, chunk_q=1024)[:, :, :n_h]
    x = x + jnp.einsum(
        "bshd,hdm->bsm", o, p["wo"].astype(dt).reshape(cfg.n_heads, hd, d)
    )
    return _mlp(p, cfg, x)


# ---------------------------------------------------------------------------
# LM assembly
# ---------------------------------------------------------------------------


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    kinds = cfg.layer_kinds()
    ks = jax.random.split(rng, cfg.n_layers + 2)
    blocks = [init_block(ks[i], cfg, kind) for i, kind in enumerate(kinds)]
    return {
        "embed": embed_init(ks[-2], (cfg.vocab_size, cfg.d_model), cfg.pdt),
        "final_norm": jnp.zeros((cfg.d_model,), cfg.pdt),
        "blocks": blocks,
    }


def forward(params, cfg: ModelConfig, tokens, *, remat: bool = True, **_):
    x = shard_act(
        params["embed"].astype(cfg.cdt)[tokens] * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.cdt)),
        "dp", None, None,
    )
    positions = jnp.arange(tokens.shape[1])[None, :]
    for kind, p in zip(cfg.layer_kinds(), params["blocks"]):
        if kind == "rglru":
            fn = jax.checkpoint(rglru_block, static_argnums=(1,)) if remat else rglru_block
            x = fn(p, cfg, x)
        else:
            fn = jax.checkpoint(attn_block, static_argnums=(1,)) if remat else attn_block
            x = fn(p, cfg, x, positions)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.cdt))  # tied
    return shard_act(logits, "dp", None, "tp"), jnp.zeros((), jnp.float32)


def lm_loss(params, cfg: ModelConfig, tokens, *, remat: bool = True, **_):
    logits, _ = forward(params, cfg, tokens, remat=remat)
    lf = logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    lse = jax.nn.logsumexp(lf, axis=-1)
    # gold logit via mask+reduce: shards over the TP vocab dim with a
    # scalar psum, where take_along_axis all-gathers the logits tensor
    vocab_iota = jnp.arange(lf.shape[-1], dtype=tgt.dtype)
    gold = jnp.sum(jnp.where(vocab_iota == tgt[..., None], lf, 0.0), axis=-1)
    ce = jnp.mean(lse - gold)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# -- decode: ring-buffer KV for attn layers, O(1) state for rglru -----------


def init_state(params, cfg: ModelConfig, b: int, s_max: int = 0):
    """Decode state: O(1) rglru state + ring-buffer KV of window size.

    Shapes depend only on cfg (``params`` may be None — dry-run builds
    the struct without weights)."""
    del params, s_max
    win = cfg.window or 2048
    states: List[Dict[str, jnp.ndarray]] = []
    for kind in cfg.layer_kinds():
        if kind == "rglru":
            states.append(
                {
                    "h": jnp.zeros((b, cfg.d_model), jnp.float32),
                    "conv": jnp.zeros((b, cfg.conv_width - 1, cfg.d_model), cfg.cdt),
                }
            )
        else:
            states.append(
                {
                    "k": jnp.zeros((b, win, cfg.n_kv_heads, cfg.hd), cfg.cdt),
                    "v": jnp.zeros((b, win, cfg.n_kv_heads, cfg.hd), cfg.cdt),
                    "slot_pos": jnp.full((win,), -1, jnp.int32),
                }
            )
    return {"layers": states, "pos": jnp.zeros((), jnp.int32)}


def _attn_decode(p, cfg: ModelConfig, st, x, pos):
    dt = x.dtype
    b = x.shape[0]
    hd = cfg.hd
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    q = jnp.einsum("bsd,dq->bsq", h, p["wq"].astype(dt)).reshape(b, 1, cfg.n_heads, hd)
    k = jnp.einsum("bsd,dq->bsq", h, p["wk"].astype(dt)).reshape(b, 1, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,dq->bsq", h, p["wv"].astype(dt)).reshape(b, 1, cfg.n_kv_heads, hd)
    posb = pos[None, None]
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    win = st["k"].shape[1]
    slot = pos % win
    kc = jax.lax.dynamic_update_slice(st["k"], k.astype(st["k"].dtype), (0, slot, 0, 0))
    vc = jax.lax.dynamic_update_slice(st["v"], v.astype(st["v"].dtype), (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(st["slot_pos"], pos[None], (slot,))
    kf = repeat_kv(kc, cfg.n_heads)
    vf = repeat_kv(vc, cfg.n_heads)
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32) * hd ** -0.5, kf.astype(jnp.float32)
    )
    valid = (slot_pos >= 0) & (slot_pos <= pos) & (slot_pos > pos - win)
    scores = jnp.where(valid[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(vf.dtype), vf)
    x = x + jnp.einsum(
        "bshd,hdm->bsm", o, p["wo"].astype(dt).reshape(cfg.n_heads, hd, cfg.d_model)
    )
    return {"k": kc, "v": vc, "slot_pos": slot_pos}, _mlp(p, cfg, x)


def _rglru_decode(p, cfg: ModelConfig, st, x):
    dt = x.dtype
    h = rms_norm(x[:, 0], p["ln1"], cfg.norm_eps)
    xr = jnp.einsum("bd,de->be", h, p["w_x"].astype(dt))
    gate = jax.nn.gelu(
        jnp.einsum("bd,de->be", h, p["w_gate"].astype(dt)).astype(jnp.float32),
        approximate=True,
    ).astype(dt)
    conv_out, conv_state = _conv_step(xr, st["conv"], p["conv_w"], p["conv_b"])
    hnew, y = rglru_step(p, st["h"], conv_out)
    x = x + jnp.einsum("be,ed->bd", y * gate, p["w_out"].astype(dt))[:, None]
    return {"h": hnew, "conv": conv_state}, _mlp(p, cfg, x)


def decode_step(params, cfg: ModelConfig, state, tokens):
    x = params["embed"].astype(cfg.cdt)[tokens] * jnp.sqrt(
        jnp.asarray(cfg.d_model, cfg.cdt)
    )
    pos = state["pos"]
    new_states = []
    for kind, p, st in zip(cfg.layer_kinds(), params["blocks"], state["layers"]):
        if kind == "rglru":
            st2, x = _rglru_decode(p, cfg, st, x)
        else:
            st2, x = _attn_decode(p, cfg, st, x, pos)
        new_states.append(st2)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["embed"].astype(cfg.cdt))
    return logits, {"layers": new_states, "pos": pos + 1}


def _ring_from_full(k, v, s, win, cfg: ModelConfig):
    """Place the last `win` roped K/V at their ring slots (slot = pos % win)."""
    b = k.shape[0]
    kc = jnp.zeros((b, win, cfg.n_kv_heads, cfg.hd), cfg.cdt)
    vc = jnp.zeros((b, win, cfg.n_kv_heads, cfg.hd), cfg.cdt)
    n_keep = min(s, win)
    last_k = k[:, s - n_keep :].astype(cfg.cdt)     # (b, n_keep, kv, hd)
    last_v = v[:, s - n_keep :].astype(cfg.cdt)
    pos = jnp.arange(s - n_keep, s)                  # absolute positions
    slots = pos % win
    kc = kc.at[:, slots].set(last_k)
    vc = vc.at[:, slots].set(last_v)
    slot_pos = jnp.full((win,), -1, jnp.int32).at[slots].set(pos.astype(jnp.int32))
    return {"k": kc, "v": vc, "slot_pos": slot_pos}


def _conv_tail(xr, w: int):
    """Last w pre-conv inputs, left-padded when the prompt is shorter."""
    s = xr.shape[1]
    tail = xr[:, max(0, s - w):]
    if tail.shape[1] < w:
        tail = jnp.pad(tail, ((0, 0), (w - tail.shape[1], 0), (0, 0)))
    return tail


def prefill(params, cfg: ModelConfig, tokens, *, s_max: Optional[int] = None, **_):
    """Parallel prefill: one teacher-forced forward pass that *also*
    extracts the decode state per layer (RG-LRU carry + conv tail, or the
    last-``window`` ring KV slots).

    Replaces the original token-by-token decode scan, whose per-token
    FSDP weight gathers made this the most collective-bound cell of the
    whole §Roofline baseline (see EXPERIMENTS.md §Perf before/after).
    """
    b, s = tokens.shape
    win = cfg.window or 2048
    positions = jnp.arange(s)[None, :]
    x = shard_act(
        params["embed"].astype(cfg.cdt)[tokens]
        * jnp.sqrt(jnp.asarray(cfg.d_model, cfg.cdt)),
        "dp", None, None,
    )
    states: List[Dict[str, jnp.ndarray]] = []
    for kind, p in zip(cfg.layer_kinds(), params["blocks"]):
        if kind == "rglru":
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            dt = h.dtype
            xr = shard_act(
                jnp.einsum("bsd,de->bse", h, p["w_x"].astype(dt)), "dp", None, "tp"
            )
            gate = jax.nn.gelu(
                jnp.einsum("bsd,de->bse", h, p["w_gate"].astype(dt)).astype(jnp.float32),
                approximate=True,
            ).astype(dt)
            xr_c = _conv_causal(xr, p["conv_w"], p["conv_b"])
            a, bb = _rglru_coeffs(p, xr_c)
            _, h_all = jax.lax.associative_scan(_combine, (a, bb), axis=1)
            y = shard_act(h_all.astype(dt), "dp", None, "tp")
            x = shard_act(
                x + jnp.einsum("bse,ed->bsd", y * gate, p["w_out"].astype(dt)),
                "dp", None, None,
            )
            x = _mlp(p, cfg, x)
            states.append(
                {
                    "h": h_all[:, -1].astype(jnp.float32),
                    "conv": _conv_tail(xr, cfg.conv_width - 1).astype(cfg.cdt),
                }
            )
        else:
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            dt = h.dtype
            hd = cfg.hd
            q = jnp.einsum("bsd,dq->bsq", h, p["wq"].astype(dt)).reshape(b, s, cfg.n_heads, hd)
            k = jnp.einsum("bsd,dq->bsq", h, p["wk"].astype(dt)).reshape(b, s, cfg.n_kv_heads, hd)
            v = jnp.einsum("bsd,dq->bsq", h, p["wv"].astype(dt)).reshape(b, s, cfg.n_kv_heads, hd)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            from repro.models.attention import pad_heads_for_tp

            qp, kp, vp, n_h = pad_heads_for_tp(q, k, v)
            qp = shard_act(qp, "dp", None, "tp", None)
            o = attention(qp, kp, vp, causal=True, window=cfg.window, chunk_q=1024)[:, :, :n_h]
            x = x + jnp.einsum(
                "bshd,hdm->bsm", o, p["wo"].astype(dt).reshape(cfg.n_heads, hd, cfg.d_model)
            )
            x = _mlp(p, cfg, x)
            states.append(_ring_from_full(k, v, s, win, cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(cfg.cdt))
    return {"layers": states, "pos": jnp.asarray(s, jnp.int32)}, logits
