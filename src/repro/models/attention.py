"""GQA attention: plain, query-chunked (memory-efficient), and cached decode.

Query-chunked attention bounds the live score tensor to
``(B, H, chunk_q, S_k)`` via ``lax.scan`` — the pure-JAX analogue of a
flash kernel's outer loop, and what the 32k-prefill shape cells rely on
to pass compile-time memory analysis.  All softmax math in f32.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _scores_mask(
    s_q: int, s_k: int, *, causal: bool, window: Optional[int], q_offset
) -> jnp.ndarray:
    qi = jnp.arange(s_q)[:, None] + q_offset
    ki = jnp.arange(s_k)[None, :]
    m = jnp.ones((s_q, s_k), bool)
    if causal:
        m &= ki <= qi
    if window is not None:
        m &= ki > qi - window
    return m


def pad_heads_for_tp(q, k, v):
    """Zero-pad the head dim to the next TP multiple.

    Archs whose head count doesn't divide TP (llama4: 40, gemma: 10,
    whisper: 12 over 16-way TP) would otherwise replicate the whole
    attention computation on every model shard — measured 5x compute
    inflation on llama4 train.  Padded heads produce garbage that is
    sliced off before the output projection, so numerics are unchanged.

    Returns (q, k, v, original_head_count); no-op without a mesh context
    or when heads already divide TP.
    """
    from repro.models.sharding import tp_size

    tp = tp_size()
    h = q.shape[2]
    if tp <= 1 or h % tp == 0:
        return q, k, v, h
    k = repeat_kv(k, h)
    v = repeat_kv(v, h)
    hp = -(-h // tp) * tp
    pad = ((0, 0), (0, 0), (0, hp - h), (0, 0))
    return jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad), h


def repeat_kv(k: jnp.ndarray, h_q: int) -> jnp.ndarray:
    """(B, S, H_kv, hd) -> (B, S, H_q, hd) by group repetition.

    Keeps every attention tensor at H_q heads so the "model" (TP) axis
    shards the head dim uniformly — GQA's memory win stays in the cache,
    which remains H_kv.
    """
    h_kv = k.shape[2]
    if h_kv == h_q:
        return k
    if h_q % h_kv:
        raise ValueError(f"n_heads {h_q} not a multiple of n_kv_heads {h_kv}")
    return jnp.repeat(k, h_q // h_kv, axis=2)


def _attend_block(q, k, v, mask) -> jnp.ndarray:
    """q: (B,Sq,H,hd) k/v: (B,Sk,H,hd) mask: (Sq,Sk) -> (B,Sq,H,hd)."""
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    chunk_q: Optional[int] = 1024,
) -> jnp.ndarray:
    """q: (B, Sq, Hq, hd); k, v: (B, Sk, Hkv, hd) -> (B, Sq, Hq, hd)."""
    b, s_q, h_q, hd = q.shape
    _, s_k, _, _ = k.shape
    k = repeat_kv(k, h_q)
    v = repeat_kv(v, h_q)

    if chunk_q is None or s_q <= chunk_q or s_q % chunk_q:
        mask = _scores_mask(s_q, s_k, causal=causal, window=window, q_offset=q_offset)
        return _attend_block(q, k, v, mask)

    n_chunks = s_q // chunk_q
    qc = q.reshape(b, n_chunks, chunk_q, h_q, hd).transpose(1, 0, 2, 3, 4)

    # banded path: local attention only ever sees `window + chunk_q` keys
    # per query chunk — at 32k a 2048-window band is ~10x fewer scores
    # (and collectives) than masking the dense (S x S) product.
    band = None
    if window is not None and causal and q_offset == 0 and s_k == s_q:
        band = window + chunk_q
        if band >= s_k:
            band = None

    def step(_, args):
        qi, idx = args
        off = q_offset + idx * chunk_q
        if band is None:
            mask = _scores_mask(
                chunk_q, s_k, causal=causal, window=window, q_offset=off
            )
            return None, _attend_block(qi, k, v, mask)
        start = jnp.clip(off + chunk_q - band, 0, s_k - band)
        kb = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
        qi_pos = off + jnp.arange(chunk_q)[:, None]
        kb_pos = start + jnp.arange(band)[None, :]
        mask = (kb_pos <= qi_pos) & (kb_pos > qi_pos - window)
        return None, _attend_block(qi, kb, vb, mask)

    _, outs = jax.lax.scan(step, None, (qc, jnp.arange(n_chunks)))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s_q, h_q, hd)


# ---------------------------------------------------------------------------
# KV cache (decode)
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jnp.ndarray     # (B, S_max, H_kv, hd)
    v: jnp.ndarray
    pos: jnp.ndarray   # () int32 — tokens already in the cache

    @staticmethod
    def zeros(b: int, s_max: int, h_kv: int, hd: int, dtype) -> "KVCache":
        return KVCache(
            k=jnp.zeros((b, s_max, h_kv, hd), dtype),
            v=jnp.zeros((b, s_max, h_kv, hd), dtype),
            pos=jnp.zeros((), jnp.int32),
        )


def cache_update(cache: KVCache, k_new: jnp.ndarray, v_new: jnp.ndarray) -> KVCache:
    """Append S_new tokens at cache.pos (dynamic)."""
    b, s_new = k_new.shape[0], k_new.shape[1]
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, cache.pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, cache.pos, 0, 0))
    return KVCache(k=k, v=v, pos=cache.pos + s_new)


def decode_attention(
    q: jnp.ndarray, cache: KVCache, *, window: Optional[int] = None
) -> jnp.ndarray:
    """Single-step attention against the cache.

    q: (B, 1, Hq, hd).  The cache is full-length; masking restricts to
    positions < pos (and the window, if local attention).
    """
    b, s_q, h_q, hd = q.shape
    s_k = cache.k.shape[1]
    k = repeat_kv(cache.k, h_q)
    v = repeat_kv(cache.v, h_q)
    scale = hd ** -0.5
    scores = jnp.einsum(
        "bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, k.astype(jnp.float32)
    )
    ki = jnp.arange(s_k)[None, :]
    qi = (cache.pos - s_q) + jnp.arange(s_q)[:, None]  # new tokens' positions
    mask = ki <= qi
    if window is not None:
        mask &= ki > qi - window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
