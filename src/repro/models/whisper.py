"""Whisper-style encoder-decoder (arXiv:2212.04356) — backbone only.

The mel-spectrogram/conv frontend is a STUB per the assignment:
``input_specs()`` feeds precomputed frame embeddings ``(B, enc_seq,
d_model)`` (what the two conv layers would produce).  Everything else is
real: sinusoidal-position encoder, causal decoder with cross-attention,
pre-LayerNorm blocks with biases, GELU MLPs, tied decoder embedding.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import KVCache, attention, cache_update, decode_attention
from repro.models.config import ModelConfig
from repro.models.layers import (
    dense_init,
    embed_init,
    gelu_mlp,
    layer_norm,
    sinusoidal_positions,
    sinusoidal_positions_at,
)
from repro.models.sharding import shard_act


def _init_attn(ks, cfg: ModelConfig, *, cross: bool = False):
    d = cfg.d_model
    hd = cfg.hd
    q_dim = cfg.n_heads * hd
    kv_dim = cfg.n_kv_heads * hd
    return {
        "wq": dense_init(ks[0], (d, q_dim), cfg.pdt),
        "bq": jnp.zeros((q_dim,), cfg.pdt),
        "wk": dense_init(ks[1], (d, kv_dim), cfg.pdt),
        "wv": dense_init(ks[2], (d, kv_dim), cfg.pdt),
        "bv": jnp.zeros((kv_dim,), cfg.pdt),
        "wo": dense_init(ks[3], (q_dim, d), cfg.pdt),
        "bo": jnp.zeros((d,), cfg.pdt),
    }


def _init_layer(rng, cfg: ModelConfig, *, decoder: bool):
    ks = jax.random.split(rng, 16)
    d, ff = cfg.d_model, cfg.d_ff
    p = {
        "ln1_s": jnp.ones((d,), cfg.pdt), "ln1_b": jnp.zeros((d,), cfg.pdt),
        "self": _init_attn(ks[0:4], cfg),
        "ln2_s": jnp.ones((d,), cfg.pdt), "ln2_b": jnp.zeros((d,), cfg.pdt),
        "w_in": dense_init(ks[4], (d, ff), cfg.pdt),
        "b_in": jnp.zeros((ff,), cfg.pdt),
        "w_out": dense_init(ks[5], (ff, d), cfg.pdt),
        "b_out": jnp.zeros((d,), cfg.pdt),
    }
    if decoder:
        p["lnx_s"] = jnp.ones((d,), cfg.pdt)
        p["lnx_b"] = jnp.zeros((d,), cfg.pdt)
        p["cross"] = _init_attn(ks[6:10], cfg)
    return p


def init_params(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    enc_layers = jax.vmap(lambda r: _init_layer(r, cfg, decoder=False))(
        jax.random.split(k1, cfg.n_enc_layers)
    )
    dec_layers = jax.vmap(lambda r: _init_layer(r, cfg, decoder=True))(
        jax.random.split(k2, cfg.n_layers)
    )
    d = cfg.d_model
    return {
        "enc_pos": jnp.asarray(sinusoidal_positions(cfg.enc_seq, d), cfg.pdt),
        "enc_layers": enc_layers,
        "enc_ln_s": jnp.ones((d,), cfg.pdt), "enc_ln_b": jnp.zeros((d,), cfg.pdt),
        "embed": embed_init(k3, (cfg.vocab_size, d), cfg.pdt),
        # decoder positions are analytic sinusoids (whisper's learned
        # table is a stub here; analytic = unbounded context for the
        # synthetic 32k decode cells)
        "dec_layers": dec_layers,
        "dec_ln_s": jnp.ones((d,), cfg.pdt), "dec_ln_b": jnp.zeros((d,), cfg.pdt),
    }


def _mha(p, cfg: ModelConfig, xq, xkv, *, causal: bool, chunk_q=1024):
    dt = xq.dtype
    b, sq, d = xq.shape
    hd = cfg.hd
    q = (jnp.einsum("bsd,dq->bsq", xq, p["wq"].astype(dt)) + p["bq"].astype(dt)).reshape(
        b, sq, cfg.n_heads, hd
    )
    k = jnp.einsum("bsd,dq->bsq", xkv, p["wk"].astype(dt)).reshape(
        b, -1, cfg.n_kv_heads, hd
    )
    v = (jnp.einsum("bsd,dq->bsq", xkv, p["wv"].astype(dt)) + p["bv"].astype(dt)).reshape(
        b, -1, cfg.n_kv_heads, hd
    )
    # 12 heads pad to 16 so TP shards them (padded heads sliced off)
    from repro.models.attention import pad_heads_for_tp

    qp, kp, vp, n_h = pad_heads_for_tp(q, k, v)
    qp = shard_act(qp, "dp", None, "tp", None)
    o = attention(qp, kp, vp, causal=causal, chunk_q=chunk_q)[:, :, :n_h]
    return (
        jnp.einsum("bshd,hdm->bsm", o, p["wo"].astype(dt).reshape(cfg.n_heads, hd, d))
        + p["bo"].astype(dt),
        k,
        v,
    )


def encode(params, cfg: ModelConfig, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, enc_seq, d) stub embeddings -> encoder memory."""
    x = frames.astype(cfg.cdt) + params["enc_pos"].astype(cfg.cdt)[None]

    def body(h, lp):
        a, _, _ = _mha(
            lp["self"], cfg, layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps),
            layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps), causal=False,
        )
        h = h + a
        m = gelu_mlp(
            layer_norm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps),
            lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"],
        )
        return h + m, None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return layer_norm(x, params["enc_ln_s"], params["enc_ln_b"], cfg.norm_eps)


def decode_train(params, cfg: ModelConfig, tokens, memory, *, remat=True):
    x = params["embed"].astype(cfg.cdt)[tokens]
    s = tokens.shape[1]
    x = x + sinusoidal_positions_at(jnp.arange(s), cfg.d_model).astype(cfg.cdt)[None]

    def body(h, lp):
        a, _, _ = _mha(
            lp["self"], cfg, layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps),
            layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps), causal=True,
        )
        h = h + a
        c, _, _ = _mha(
            lp["cross"], cfg, layer_norm(h, lp["lnx_s"], lp["lnx_b"], cfg.norm_eps),
            memory, causal=False,
        )
        h = h + c
        m = gelu_mlp(
            layer_norm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps),
            lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"],
        )
        return h + m, None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
    x = layer_norm(x, params["dec_ln_s"], params["dec_ln_b"], cfg.norm_eps)
    return jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(cfg.cdt))


def forward(params, cfg: ModelConfig, tokens, *, frames=None, remat=True, **_):
    memory = encode(params, cfg, frames)
    return decode_train(params, cfg, tokens, memory, remat=remat), jnp.zeros(
        (), jnp.float32
    )


def lm_loss(params, cfg: ModelConfig, tokens, *, frames=None, remat=True, **_):
    logits, _ = forward(params, cfg, tokens, frames=frames, remat=remat)
    lf = logits[:, :-1].astype(jnp.float32)
    tgt = tokens[:, 1:]
    lse = jax.nn.logsumexp(lf, axis=-1)
    # gold logit via mask+reduce: shards over the TP vocab dim with a
    # scalar psum, where take_along_axis all-gathers the logits tensor
    vocab_iota = jnp.arange(lf.shape[-1], dtype=tgt.dtype)
    gold = jnp.sum(jnp.where(vocab_iota == tgt[..., None], lf, 0.0), axis=-1)
    ce = jnp.mean(lse - gold)
    return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


class WhisperCache(NamedTuple):
    k: jnp.ndarray       # (L, B, S_max, H_kv, hd) decoder self-attn
    v: jnp.ndarray
    xk: jnp.ndarray      # (L, B, enc_seq, H_kv, hd) cross K (static)
    xv: jnp.ndarray
    pos: jnp.ndarray


def init_cache(params, cfg: ModelConfig, memory, b: int, s_max: int) -> WhisperCache:
    """Precompute cross K/V from encoder memory; empty self cache."""
    def cross_kv(lp):
        dt = cfg.cdt
        k = jnp.einsum("bsd,dq->bsq", memory, lp["cross"]["wk"].astype(dt)).reshape(
            b, -1, cfg.n_kv_heads, cfg.hd
        )
        v = (
            jnp.einsum("bsd,dq->bsq", memory, lp["cross"]["wv"].astype(dt))
            + lp["cross"]["bv"].astype(dt)
        ).reshape(b, -1, cfg.n_kv_heads, cfg.hd)
        return k, v

    xk, xv = jax.vmap(cross_kv)(params["dec_layers"])
    return WhisperCache(
        k=jnp.zeros((cfg.n_layers, b, s_max, cfg.n_kv_heads, cfg.hd), cfg.cdt),
        v=jnp.zeros((cfg.n_layers, b, s_max, cfg.n_kv_heads, cfg.hd), cfg.cdt),
        xk=xk, xv=xv, pos=jnp.zeros((), jnp.int32),
    )


def decode_step(params, cfg: ModelConfig, cache: WhisperCache, tokens):
    b = tokens.shape[0]
    dt = cfg.cdt
    x = params["embed"].astype(dt)[tokens]
    x = x + sinusoidal_positions_at(cache.pos[None], cfg.d_model).astype(dt)[None]

    def body(h, layer):
        lp, kc, vc, xk, xv = layer
        hn = layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        hd = cfg.hd
        q = (jnp.einsum("bsd,dq->bsq", hn, lp["self"]["wq"].astype(dt)) + lp["self"]["bq"].astype(dt)).reshape(b, 1, cfg.n_heads, hd)
        k = jnp.einsum("bsd,dq->bsq", hn, lp["self"]["wk"].astype(dt)).reshape(b, 1, cfg.n_kv_heads, hd)
        v = (jnp.einsum("bsd,dq->bsq", hn, lp["self"]["wv"].astype(dt)) + lp["self"]["bv"].astype(dt)).reshape(b, 1, cfg.n_kv_heads, hd)
        lc = KVCache(k=kc, v=vc, pos=cache.pos)
        lc = cache_update(lc, k, v)
        o = decode_attention(q, lc)
        h = h + (
            jnp.einsum("bshd,hdm->bsm", o, lp["self"]["wo"].astype(dt).reshape(cfg.n_heads, hd, cfg.d_model))
            + lp["self"]["bo"].astype(dt)
        )
        # cross attention against static memory K/V
        hx = layer_norm(h, lp["lnx_s"], lp["lnx_b"], cfg.norm_eps)
        qx = (jnp.einsum("bsd,dq->bsq", hx, lp["cross"]["wq"].astype(dt)) + lp["cross"]["bq"].astype(dt)).reshape(b, 1, cfg.n_heads, hd)
        xc = KVCache(k=xk, v=xv, pos=jnp.array(xk.shape[1], jnp.int32))
        ox = decode_attention(qx, xc)
        h = h + (
            jnp.einsum("bshd,hdm->bsm", ox, lp["cross"]["wo"].astype(dt).reshape(cfg.n_heads, hd, cfg.d_model))
            + lp["cross"]["bo"].astype(dt)
        )
        m = gelu_mlp(
            layer_norm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps),
            lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"],
        )
        return h + m, (lc.k, lc.v)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache.k, cache.v, cache.xk, cache.xv)
    )
    x = layer_norm(x, params["dec_ln_s"], params["dec_ln_b"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, 0], params["embed"].astype(dt))
    return logits, cache._replace(k=ks, v=vs, pos=cache.pos + 1)


def prefill(params, cfg: ModelConfig, tokens, *, frames=None, s_max=None, **_):
    """Encode frames + ONE teacher-forced decoder pass that collects the
    self-attention K/V cache (replaces the token-by-token decode scan,
    which both stacked 32k cache copies and issued per-token collectives)."""
    memory = encode(params, cfg, frames)
    b, s = tokens.shape
    s_max = max(s_max or s, s)
    dt = cfg.cdt
    x = params["embed"].astype(dt)[tokens]
    x = x + sinusoidal_positions_at(jnp.arange(s), cfg.d_model).astype(dt)[None]
    pad = s_max - s

    def body(h, lp):
        hn = layer_norm(h, lp["ln1_s"], lp["ln1_b"], cfg.norm_eps)
        a, k, v = _mha(lp["self"], cfg, hn, hn, causal=True)
        h = h + a
        c, _, _ = _mha(
            lp["cross"], cfg, layer_norm(h, lp["lnx_s"], lp["lnx_b"], cfg.norm_eps),
            memory, causal=False,
        )
        h = h + c
        m = gelu_mlp(
            layer_norm(h, lp["ln2_s"], lp["ln2_b"], cfg.norm_eps),
            lp["w_in"], lp["b_in"], lp["w_out"], lp["b_out"],
        )
        if pad:
            k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = shard_act(k.astype(dt), "dp", None, None, "tp")
        v = shard_act(v.astype(dt), "dp", None, None, "tp")
        return h + m, (k, v)

    x, (ks, vs) = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
    x = layer_norm(x, params["dec_ln_s"], params["dec_ln_b"], cfg.norm_eps)
    logits = jnp.einsum("bd,vd->bv", x[:, -1], params["embed"].astype(dt))
    base = init_cache(params, cfg, memory, b, s_max)
    cache = base._replace(k=ks, v=vs, pos=jnp.asarray(s, jnp.int32))
    return cache, logits
