"""Partition-spec rules: map every param/batch/cache leaf to mesh axes.

Scheme: FSDP over ("pod", "data") — weights sharded on a feature dim,
gathered just-in-time by GSPMD — and tensor parallelism over "model".
Rules are name+rank based and *divisibility-guarded*: a dim is only
sharded by axes whose size product divides it (e.g. whisper's vocab
51865 stays unsharded; 10-head attention replicates heads but still
shards d_ff).  GSPMD propagates everything else.
"""
from __future__ import annotations

import contextvars
import math
from contextlib import contextmanager
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.utils.treelib import flatten_with_names

# ---------------------------------------------------------------------------
# Activation-sharding context: model code calls shard_act(x, "dp", None,
# "tp") at layer boundaries; constraints are no-ops unless a harness has
# activated a mesh (GSPMD otherwise drops batch sharding across
# remat+scan boundaries and replicates compute — observed 8x flop
# inflation on the 16x16 mesh without these pins).
# ---------------------------------------------------------------------------

_ACT_RULES: "contextvars.ContextVar[Optional[dict]]" = contextvars.ContextVar(
    "repro_act_rules", default=None
)


@contextmanager
def activation_sharding(mesh: Mesh):
    """Enable bare-PartitionSpec activation constraints for this mesh."""
    rules = {
        "dp": fsdp_axes(mesh),
        "tp": "model",
        "sizes": {a: int(mesh.shape[a]) for a in mesh.axis_names},
    }
    jax.set_mesh(mesh)
    token = _ACT_RULES.set(rules)
    try:
        yield
    finally:
        _ACT_RULES.reset(token)


def tp_size() -> int:
    """Active TP degree (1 when no mesh context is active)."""
    rules = _ACT_RULES.get()
    if rules is None:
        return 1
    return int(rules["sizes"].get(rules["tp"], 1))


def shard_act(x, *kinds):
    """Constrain activation dims: kinds from {"dp", "tp", None} per dim.

    Divisibility-guarded: an axis that does not divide the dim is
    dropped (e.g. 10-head attention under 16-way TP replicates heads).
    """
    rules = _ACT_RULES.get()
    if rules is None:
        return x
    sizes = rules["sizes"]

    def ok(dim: int, axes) -> Optional[Any]:
        if axes is None:
            return None
        seq = (axes,) if isinstance(axes, str) else tuple(axes)
        prod = 1
        for a in seq:
            prod *= sizes.get(a, 1)
        if dim % prod == 0:
            return axes
        for k in range(len(seq) - 1, 0, -1):
            prod = 1
            for a in seq[:k]:
                prod *= sizes.get(a, 1)
            if dim % prod == 0:
                return seq[:k]
        return None

    spec = P(*[ok(x.shape[i], rules.get(k) if k else None) for i, k in enumerate(kinds)])
    return jax.lax.with_sharding_constraint(x, spec)

# leaf-name fragments whose *first* big axis is the contraction output
# (down-projections: shard input dim by TP, output dim by FSDP)
_DOWN_NAMES = ("w_down", "wo", "m_down", "w_out", "shared_down")
_REPLICATE_NAMES = (
    "ln", "final_norm", "gn", "_s']", "_b']", "conv_b", "lam", "b_r", "b_i",
    "bq", "bk", "bv", "bo", "b_in", "b_out", "['b']", "['r']", "enc_pos",
    "dec_pos", "pos",
)


def axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _maybe(dim: int, axes, mesh: Mesh):
    """axes if they evenly divide dim else None."""
    if axes is None:
        return None
    if dim % axis_size(mesh, axes) == 0:
        return axes
    # try a prefix (e.g. ("pod","data") -> ("pod",))
    if not isinstance(axes, str) and len(axes) > 1:
        for k in range(len(axes) - 1, 0, -1):
            sub = axes[:k]
            if dim % axis_size(mesh, sub) == 0:
                return sub
    return None


def param_spec_for(name: str, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh) -> P:
    F = fsdp_axes(mesh)
    T = "model"
    if any(frag in name for frag in _REPLICATE_NAMES):
        return P()
    if "embed" in name:
        # Lookup table: vocab over TP, d replicated.  Sharding d would
        # make XLA reshard the gather *output*, which miscompiles on the
        # jax 0.8 CPU SPMD partitioner ("slice dim size > dynamic slice
        # dimension"); vocab-sharded gathers lower to the standard
        # mask+all-reduce pattern instead.  The untied `out` projection
        # is a plain matmul and stays sharded on both dims.
        v, d = shape
        return P(_maybe(v, T, mesh), None)
    if "'out'" in name or name.endswith("out']") and "w_out" not in name:
        v, d = shape
        return P(_maybe(v, T, mesh), _maybe(d, F, mesh))
    if "router" in name:
        return P(None, _maybe(shape[-2], F, mesh), None)
    # MoE expert stacks: (L, E, a, b)
    if len(shape) == 4 and cfg.moe is not None and "moe" in name:
        L, E, a, b = shape
        ep = _maybe(E, T, mesh)
        if ep is not None:
            return P(None, ep, _maybe(a, F, mesh), None)
        # expert-TP fallback: shard the expert feature dims
        if any(frag in name for frag in _DOWN_NAMES):
            return P(None, None, _maybe(a, T, mesh), _maybe(b, F, mesh))
        return P(None, None, _maybe(a, F, mesh), _maybe(b, T, mesh))
    down = any(frag in name for frag in _DOWN_NAMES)
    if len(shape) == 3:  # stacked layers: (L, a, b)
        _, a, b = shape
        if down:
            return P(None, _maybe(a, T, mesh), _maybe(b, F, mesh))
        return P(None, _maybe(a, F, mesh), _maybe(b, T, mesh))
    if len(shape) == 2:  # per-layer dict weights (xlstm/griffin lists)
        a, b = shape
        if "conv_w" in name:
            return P(None, _maybe(b, T, mesh))
        if down:
            return P(_maybe(a, T, mesh), _maybe(b, F, mesh))
        return P(_maybe(a, F, mesh), _maybe(b, T, mesh))
    if len(shape) == 1:
        return P()
    return P()


def param_specs(model, mesh: Mesh) -> Any:
    struct = model.param_struct()
    named, treedef = flatten_with_names(struct)
    specs = [
        param_spec_for(name, tuple(leaf.shape), model.cfg, mesh) for name, leaf in named
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batch / cache / optimizer specs
# ---------------------------------------------------------------------------


def batch_specs(model, mesh: Mesh) -> Any:
    F = fsdp_axes(mesh)

    def spec(name: str, leaf) -> P:
        rank = len(leaf.shape)
        dp = _maybe(leaf.shape[0], F, mesh)
        return P(dp, *([None] * (rank - 1)))

    struct = model.batch_struct(8 * axis_size(mesh, fsdp_axes(mesh)), 128)
    named, treedef = flatten_with_names(struct)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(n, l) for n, l in named]
    )


def cache_spec_for(name: str, shape: Tuple[int, ...], cfg: ModelConfig, mesh: Mesh) -> P:
    F = fsdp_axes(mesh)
    T = "model"
    if name.endswith("pos']") or "slot_pos" in name or shape == ():
        return P()
    # transformer / whisper stacked caches: (L, B, S, H_kv, hd)
    if len(shape) == 5:
        _, b, _, h_kv, hd = shape
        return P(
            None, _maybe(b, F, mesh), None, _maybe(h_kv, T, mesh) ,
            None if _maybe(h_kv, T, mesh) else _maybe(hd, T, mesh),
        )
    # xlstm: C (B,H,hd,hd) / conv (B,W,di) / n (B,H,hd) / m (B,H)
    if len(shape) == 4:
        b, h, hd, _ = shape
        return P(_maybe(b, F, mesh), _maybe(h, T, mesh),
                 None if _maybe(h, T, mesh) else _maybe(hd, T, mesh), None)
    if len(shape) == 3:
        b = shape[0]
        return P(_maybe(b, F, mesh), None, _maybe(shape[-1], T, mesh))
    if len(shape) == 2:
        b = shape[0]
        return P(_maybe(b, F, mesh), _maybe(shape[-1], T, mesh))
    if len(shape) == 1:
        return P(None)
    return P()


def cache_specs(model, mesh: Mesh, b: int, s_max: int) -> Any:
    struct = model.cache_struct(b, s_max)
    named, treedef = flatten_with_names(struct)
    specs = []
    for name, leaf in named:
        shape = tuple(getattr(leaf, "shape", ()))
        specs.append(cache_spec_for(name, shape, model.cfg, mesh))
    return jax.tree_util.tree_unflatten(treedef, specs)


def named(tree: Any, mesh: Mesh, specs: Any) -> Any:
    """Attach NamedShardings (for device_put of real arrays)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P),
    )
