"""Uniform model facade over the four architecture families.

Batch dict keys: ``tokens`` (always), ``patches`` (vlm), ``frames``
(audio).  Caches are family-specific pytrees; ``cache_struct`` builds
their ShapeDtypeStruct twins for the compile-only dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import griffin, transformer, whisper, xlstm
from repro.models.config import ModelConfig


def _family_module(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer
    if cfg.family == "ssm":
        return xlstm
    if cfg.family == "hybrid":
        return griffin
    if cfg.family == "audio":
        return whisper
    raise ValueError(f"unknown family {cfg.family!r}")


def _extra_kwargs(cfg: ModelConfig, batch: Dict[str, Any]) -> Dict[str, Any]:
    kw = {}
    if cfg.family == "vlm" and "patches" in batch:
        kw["patches"] = batch["patches"]
    if cfg.family == "audio":
        kw["frames"] = batch["frames"]
    return kw


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def mod(self):
        return _family_module(self.cfg)

    # -- parameters ---------------------------------------------------------
    def init(self, rng) -> Any:
        return self.mod.init_params(rng, self.cfg)

    def param_struct(self) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_count(self, *, active_only: bool = False) -> int:
        import math

        struct = self.param_struct()
        total = sum(
            math.prod(l.shape)
            for l in jax.tree_util.tree_leaves(struct)
            if hasattr(l, "shape")
        )
        if active_only and self.cfg.moe is not None:
            m = self.cfg.moe
            e_ff = m.expert_d_ff or self.cfg.d_ff
            per_layer_inactive = 3 * self.cfg.d_model * e_ff * (m.n_experts - m.top_k)
            total -= per_layer_inactive * self.cfg.n_layers
        return total

    # -- training -----------------------------------------------------------
    def loss(self, params, batch: Dict[str, Any], *, remat: bool = True):
        return self.mod.lm_loss(
            params, self.cfg, batch["tokens"], remat=remat,
            **_extra_kwargs(self.cfg, batch),
        )

    def forward(self, params, batch: Dict[str, Any], *, remat: bool = False):
        return self.mod.forward(
            params, self.cfg, batch["tokens"], remat=remat,
            **_extra_kwargs(self.cfg, batch),
        )

    # -- serving --------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, Any], *, s_max: Optional[int] = None):
        return self.mod.prefill(
            params, self.cfg, batch["tokens"], s_max=s_max,
            **_extra_kwargs(self.cfg, batch),
        )

    def decode_step(self, params, cache, tokens):
        return self.mod.decode_step(params, self.cfg, cache, tokens)

    def cache_struct(self, b: int, s_max: int) -> Any:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return jax.eval_shape(lambda: transformer.init_cache(cfg, b, s_max))
        if cfg.family == "ssm":
            return jax.eval_shape(lambda: xlstm.init_state(None, cfg, b))
        if cfg.family == "hybrid":
            return jax.eval_shape(lambda: griffin.init_state(None, cfg, b))
        if cfg.family == "audio":
            return jax.eval_shape(
                lambda: whisper.WhisperCache(
                    k=jnp.zeros((cfg.n_layers, b, s_max, cfg.n_kv_heads, cfg.hd), cfg.cdt),
                    v=jnp.zeros((cfg.n_layers, b, s_max, cfg.n_kv_heads, cfg.hd), cfg.cdt),
                    xk=jnp.zeros((cfg.n_layers, b, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), cfg.cdt),
                    xv=jnp.zeros((cfg.n_layers, b, cfg.enc_seq, cfg.n_kv_heads, cfg.hd), cfg.cdt),
                    pos=jnp.zeros((), jnp.int32),
                )
            )
        raise ValueError(cfg.family)

    def init_cache(self, params, b: int, s_max: int, memory=None):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.init_cache(cfg, b, s_max)
        if cfg.family == "ssm":
            return xlstm.init_state(params, cfg, b)
        if cfg.family == "hybrid":
            return griffin.init_state(params, cfg, b)
        if cfg.family == "audio":
            return whisper.init_cache(params, cfg, memory, b, s_max)
        raise ValueError(cfg.family)

    # -- dry-run inputs -------------------------------------------------------
    def batch_struct(self, batch_size: int, seq_len: int) -> Dict[str, Any]:
        cfg = self.cfg
        i32 = jnp.int32
        if cfg.family == "vlm":
            n_text = max(1, seq_len - cfg.n_patches)
            return {
                "tokens": jax.ShapeDtypeStruct((batch_size, n_text), i32),
                "patches": jax.ShapeDtypeStruct(
                    (batch_size, cfg.n_patches, cfg.d_model), cfg.cdt
                ),
            }
        if cfg.family == "audio":
            return {
                "tokens": jax.ShapeDtypeStruct((batch_size, seq_len), i32),
                "frames": jax.ShapeDtypeStruct(
                    (batch_size, cfg.enc_seq, cfg.d_model), cfg.cdt
                ),
            }
        return {"tokens": jax.ShapeDtypeStruct((batch_size, seq_len), i32)}


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)
