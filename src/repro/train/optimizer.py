"""AdamW + schedules, from scratch (no optax).

Optimizer moments can live in a reduced dtype (``state_dtype='bfloat16'``)
— at 405B that is the difference between fitting and not fitting v5e HBM
alongside FSDP-sharded bf16 params (see EXPERIMENTS.md §Roofline).
Update math always runs in f32.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Optional[str] = None   # None -> match param dtype
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"            # cosine | constant
    min_lr_ratio: float = 0.1


def lr_at(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, cfg.warmup_steps))
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params: Any, cfg: OptConfig) -> Dict[str, Any]:
    def z(p):
        dt = jnp.dtype(cfg.state_dtype) if cfg.state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)

    return {
        "mu": jax.tree_util.tree_map(z, params),
        "nu": jax.tree_util.tree_map(z, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    grads: Any, opt_state: Dict[str, Any], params: Any, cfg: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    count = opt_state["count"] + 1
    cf = count.astype(jnp.float32)
    gnorm = global_norm(grads)
    scale = jnp.where(
        gnorm > cfg.grad_clip, cfg.grad_clip / jnp.maximum(gnorm, 1e-9), 1.0
    )
    lr = lr_at(cfg, opt_state["count"])
    bc1 = 1.0 - cfg.beta1 ** cf
    bc2 = 1.0 - cfg.beta2 ** cf

    def upd(p, g, mu, nu):
        gf = g.astype(jnp.float32) * scale
        mu_f = cfg.beta1 * mu.astype(jnp.float32) + (1 - cfg.beta1) * gf
        nu_f = cfg.beta2 * nu.astype(jnp.float32) + (1 - cfg.beta2) * gf * gf
        step = (mu_f / bc1) / (jnp.sqrt(nu_f / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + cfg.weight_decay * pf)
        return pf.astype(p.dtype), mu_f.astype(mu.dtype), nu_f.astype(nu.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(opt_state["mu"])
    flat_nu = jax.tree_util.tree_leaves(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return (
        new_p,
        {"mu": new_mu, "nu": new_nu, "count": count},
        {"grad_norm": gnorm, "lr": lr},
    )
