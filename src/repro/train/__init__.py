from repro.train.optimizer import OptConfig, adamw_update, init_opt_state, lr_at
from repro.train.train_step import (
    TrainConfig,
    build_train_step,
    init_train_state,
    make_train_step,
    train_state_specs,
)

__all__ = [
    "OptConfig",
    "adamw_update",
    "init_opt_state",
    "lr_at",
    "TrainConfig",
    "build_train_step",
    "init_train_state",
    "make_train_step",
    "train_state_specs",
]
