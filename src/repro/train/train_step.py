"""Train-step builder: grad accumulation, remat, pjit shardings.

``make_train_step`` returns a jitted function over a TrainState pytree
with explicit in/out shardings derived from the model's partition rules
(FSDP over pod+data, TP over model).  Gradient accumulation runs as a
``lax.scan`` over microbatches — peak activation memory is one
microbatch deep, which is what lets llama3-405b's train_4k cell compile
inside v5e HBM.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.api import Model
from repro.models.sharding import fsdp_axes, param_specs, _maybe
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state


@dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    remat: bool = True
    accum_dtype: str = "float32"   # grad-accumulator dtype (bf16 at 405B)


def init_train_state(model: Model, rng, tcfg: TrainConfig) -> Dict[str, Any]:
    params = model.init(rng)
    return {
        "params": params,
        "opt": init_opt_state(params, tcfg.opt),
        "step": jnp.zeros((), jnp.int32),
    }


def train_state_specs(model: Model, mesh: Mesh, tcfg: TrainConfig) -> Dict[str, Any]:
    pspecs = param_specs(model, mesh)
    return {
        "params": pspecs,
        "opt": {
            "mu": pspecs,
            "nu": pspecs,
            "count": P(),
        },
        "step": P(),
    }


def batch_spec_tree(model: Model, mesh: Mesh, batch_struct: Any) -> Any:
    F = fsdp_axes(mesh)

    def spec(leaf):
        rank = len(leaf.shape)
        return P(_maybe(leaf.shape[0], F, mesh), *([None] * (rank - 1)))

    return jax.tree_util.tree_map(spec, batch_struct)


def build_train_step(model: Model, tcfg: TrainConfig):
    """The un-jitted step: (state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        loss, parts = model.loss(params, mb, remat=tcfg.remat)
        return loss, parts

    def train_step(state, batch):
        params = state["params"]
        k = tcfg.microbatches
        if k > 1:
            mbs = jax.tree_util.tree_map(
                lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch
            )

            def accum(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype) / k, g_acc, g
                )
                return (g_acc, l_acc + loss / k), None

            adt = jnp.dtype(tcfg.accum_dtype)
            g0 = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, adt), params)
            (grads, loss), _ = jax.lax.scan(
                accum, (g0, jnp.zeros((), jnp.float32)), mbs
            )
        else:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        new_params, new_opt, om = adamw_update(grads, state["opt"], params, tcfg.opt)
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        metrics = {"loss": loss.astype(jnp.float32), **om}
        return new_state, metrics

    return train_step


def make_train_step(
    model: Model,
    tcfg: TrainConfig,
    mesh: Mesh,
    batch_struct: Any,
):
    """jit with explicit shardings; returns (jitted_fn, state_specs, batch_specs)."""
    sspecs = train_state_specs(model, mesh, tcfg)
    bspecs = batch_spec_tree(model, mesh, batch_struct)
    mspec = {"loss": P(), "grad_norm": P(), "lr": P()}
    fn = jax.jit(
        build_train_step(model, tcfg),
        in_shardings=(
            jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), sspecs,
                                   is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), bspecs,
                                   is_leaf=lambda x: isinstance(x, P)),
        ),
        out_shardings=(
            jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), sspecs,
                                   is_leaf=lambda x: isinstance(x, P)),
            jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), mspec,
                                   is_leaf=lambda x: isinstance(x, P)),
        ),
        donate_argnums=(0,),
    )
    return fn, sspecs, bspecs
