"""ControlPlane: N concurrent jobs sharing one PFS through a single
arbitrated checkpoint runtime.

One ``CheckpointManager`` owns one training run; nothing in the core
runtime arbitrates *between* runs — yet production clusters (and the
paper's motivating workloads) run many jobs whose checkpoint traffic
collides on the same parallel filesystem.  The control plane is that
missing arbitration layer:

* **Registry** — ``register_job`` creates a tenant namespace
  (``<root>/jobs/<name>``) and persists its record (priority, weight,
  GC policy, geometry, pins, config) in ``<root>/control/registry.json``
  next to the PFS manifests, atomically; a fresh ``ControlPlane`` over
  the same root recovers every job after a crash or restart
  (``attach_job``).
* **Bandwidth quotas** — one global ``flush_bw_cap`` is split across
  tenants by a :class:`~repro.core.storage.FairShareLimiter`
  (weighted fair share, idle shares redistributed), each tenant's
  manager charging its own leaf exactly where a single-job manager
  charges its private :class:`~repro.core.storage.TokenBucket`.
* **Admission** — every manager shares one
  :class:`~repro.core.admission.AdmissionController`, turning
  ``max_pending_flushes`` into a cluster-wide pending-flush budget
  with priority preemption (a queued low-priority flush parks as a
  journaled ``flush_partial`` and drains later).
* **Shared breaker** — all tenants feed one
  :class:`~repro.core.storage.StorageHealth`: the PFS that went away
  went away for everyone, so tenant A's giveups open the circuit
  tenant B's flushes must respect, while B's L1 saves stay untouched.
* **Serving** — fleets subscribe to a tenant's flush-done events
  *through the plane* (``subscribe``), not a private manager handle.

The plane is a single-process arbiter by design, mirroring the rest of
this harness: tenants are threads sharing one storage tree, which is
exactly the contention surface the aggregation strategies target.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import re
import threading
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

from repro.core.admission import AdmissionController
from repro.core.cluster import ClusterSpec
from repro.core.engine import CheckpointConfig, CheckpointManager
from repro.core.storage import FairShareLimiter, StorageHealth

log = logging.getLogger(__name__)

_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,63}$")


@dataclass
class JobRecord:
    """One tenant's persisted registry entry."""

    name: str
    priority: float = 1.0
    weight: float = 1.0
    keep_n: Optional[int] = None
    n_nodes: int = 1
    procs_per_node: int = 1
    pinned: List[int] = field(default_factory=list)
    config: Dict[str, Any] = field(default_factory=dict)
    created_at: float = 0.0

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "JobRecord":
        return cls(**{k: d[k] for k in d if k in cls.__dataclass_fields__})


class ControlPlane:
    """The multi-tenant checkpoint arbiter over one PFS root.

    ``flush_bw_cap`` is the *global* PFS write budget in bytes/s
    (0 = unthrottled: tenants still share admission and the breaker,
    but not a bandwidth quota).  ``max_pending_flushes`` is the
    cluster-wide pending-flush budget all tenants draw from.
    """

    def __init__(
        self,
        root: str,
        *,
        flush_bw_cap: float = 0.0,
        max_pending_flushes: int = 2,
        health_min_ops: int = 8,
        health_error_threshold: float = 0.5,
        health_cooldown: float = 2.0,
    ):
        self.root = Path(root)
        self.control_dir = self.root / "control"
        self.jobs_dir = self.root / "jobs"
        self.control_dir.mkdir(parents=True, exist_ok=True)
        self.jobs_dir.mkdir(parents=True, exist_ok=True)
        self.flush_bw_cap = float(flush_bw_cap)
        self.limiter: Optional[FairShareLimiter] = (
            FairShareLimiter(self.flush_bw_cap)
            if self.flush_bw_cap > 0
            else None
        )
        self.admission = AdmissionController(max_pending_flushes)
        self.storage_health = StorageHealth(
            min_ops=health_min_ops,
            error_threshold=health_error_threshold,
            cooldown=health_cooldown,
        )
        self._lock = threading.RLock()
        self._records: Dict[str, JobRecord] = {}
        self._managers: Dict[str, CheckpointManager] = {}
        self._load_registry()

    # ------------------------------------------------------------- registry

    @property
    def registry_path(self) -> Path:
        return self.control_dir / "registry.json"

    def _load_registry(self) -> None:
        p = self.registry_path
        if not p.exists():
            return
        doc = json.loads(p.read_text())
        for name, rec in doc.get("jobs", {}).items():
            self._records[name] = JobRecord.from_json(rec)

    def _persist_registry(self) -> None:
        """Atomic write: the registry is the crash-recovery source of
        truth for every tenant's policy, so a torn write must never be
        observable."""
        doc = {
            "version": 1,
            "flush_bw_cap": self.flush_bw_cap,
            "max_pending_flushes": self.admission.total,
            "jobs": {n: r.to_json() for n, r in self._records.items()},
        }
        tmp = self.registry_path.with_suffix(".tmp")
        tmp.write_text(json.dumps(doc, indent=1, sort_keys=True))
        tmp.replace(self.registry_path)

    # ------------------------------------------------------------ job verbs

    def register_job(
        self,
        name: str,
        cluster: ClusterSpec,
        *,
        priority: float = 1.0,
        weight: Optional[float] = None,
        keep_n: Optional[int] = None,
        faults: Optional[Any] = None,
        **config_kw: Any,
    ) -> CheckpointManager:
        """Create a tenant and return its arbitrated manager.

        ``config_kw`` is forwarded to :class:`CheckpointConfig` (and
        persisted, so it must be JSON-serializable); ``weight``
        defaults to ``priority`` so the bandwidth quota follows the
        preemption order unless the operator splits them.  ``faults``
        (a seeded :class:`~repro.core.faults.FaultPlan`) is a harness
        surface and is NOT persisted.
        """
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid job name {name!r}")
        with self._lock:
            if name in self._records:
                raise ValueError(
                    f"job {name!r} already registered; use attach_job()"
                )
            rec = JobRecord(
                name=name,
                priority=float(priority),
                weight=float(weight if weight is not None else priority),
                keep_n=keep_n,
                n_nodes=cluster.n_nodes,
                procs_per_node=cluster.procs_per_node,
                config=dict(config_kw),
                created_at=time.time(),
            )
            self._records[name] = rec
            mgr = self._build_manager(rec, cluster, faults=faults)
            self._managers[name] = mgr
            self._persist_registry()
        log.info(
            "control plane: registered job %r (priority=%.2f weight=%.2f)",
            name, rec.priority, rec.weight,
        )
        return mgr

    def attach_job(
        self, name: str, *, cluster: Optional[ClusterSpec] = None
    ) -> CheckpointManager:
        """Rebuild a registered tenant's manager (crash-restart path).

        Geometry and config come from the persisted record;
        ``cluster`` overrides the recorded geometry (custom
        node/PFS specs are not persisted — pass them here)."""
        with self._lock:
            if name in self._managers:
                return self._managers[name]
            rec = self._records.get(name)
            if rec is None:
                raise KeyError(f"job {name!r} not in the registry")
            c = cluster if cluster is not None else ClusterSpec(
                n_nodes=rec.n_nodes, procs_per_node=rec.procs_per_node
            )
            mgr = self._build_manager(rec, c)
            self._managers[name] = mgr
            return mgr

    def _build_manager(
        self,
        rec: JobRecord,
        cluster: ClusterSpec,
        *,
        faults: Optional[Any] = None,
    ) -> CheckpointManager:
        cfg = CheckpointConfig(
            root=str(self.jobs_dir / rec.name),
            cluster=cluster,
            keep_n=rec.keep_n,
            **rec.config,
        )
        leaf = None
        if self.limiter is not None:
            try:
                leaf = self.limiter.register(rec.name, rec.weight)
            except ValueError:
                # re-attach after a detach that never unregistered
                self.limiter.unregister(rec.name)
                leaf = self.limiter.register(rec.name, rec.weight)
        mgr = CheckpointManager(
            cfg,
            faults=faults,
            limiter=leaf,
            admission=self.admission,
            storage_health=self.storage_health,
            tenant=rec.name,
            priority=rec.priority,
        )
        for s in rec.pinned:
            mgr.pin_step(s)
        return mgr

    def manager(self, name: str) -> CheckpointManager:
        with self._lock:
            if name not in self._managers:
                return self.attach_job(name)
            return self._managers[name]

    def jobs(self) -> List[str]:
        with self._lock:
            return sorted(self._records)

    def record(self, name: str) -> JobRecord:
        with self._lock:
            return self._records[name]

    # ----------------------------------------------------- per-tenant verbs

    def list_steps(self, name: str, level: str = "pfs") -> List[int]:
        """A tenant's restorable steps — and ONLY that tenant's: each
        job namespaces its manifests under its own subtree, so no
        cross-tenant step can ever appear here."""
        return self.manager(name).steps(level)

    def pin(self, name: str, step: int) -> None:
        """Pin ``step`` against GC/supersession/eviction/preemption;
        persisted, so pins survive a control-plane restart."""
        with self._lock:
            self.manager(name).pin_step(step)
            rec = self._records[name]
            if step not in rec.pinned:
                rec.pinned.append(step)
                rec.pinned.sort()
            self._persist_registry()

    def unpin(self, name: str, step: int) -> None:
        with self._lock:
            self.manager(name).unpin_step(step)
            rec = self._records[name]
            if step in rec.pinned:
                rec.pinned.remove(step)
            self._persist_registry()

    def set_gc_policy(self, name: str, keep_n: Optional[int]) -> None:
        """Per-tenant retention: replace the tenant's ``keep_n`` (None
        disables GC for that tenant).  Applies from the next flush."""
        with self._lock:
            mgr = self.manager(name)
            mgr.cfg = dataclasses.replace(mgr.cfg, keep_n=keep_n)
            self._records[name].keep_n = keep_n
            self._persist_registry()

    def restore_to_geometry(
        self,
        name: str,
        target: Any,
        cluster: ClusterSpec,
        *,
        step: Optional[int] = None,
        sharding_fn: Optional[Callable[[str, Any], Any]] = None,
    ) -> Any:
        """Elastic restore of a tenant's step onto a DIFFERENT geometry
        (the aggregated formats are geometry-independent on the read
        side).  Runs through a transient read-only manager over the
        tenant's subtree so the live manager's flush runtime is never
        disturbed."""
        with self._lock:
            live = self.manager(name)
            cfg = dataclasses.replace(
                live.cfg,
                cluster=cluster,
                async_flush=False,
                auto_resume=False,
            )
        rm = CheckpointManager(cfg, storage_health=self.storage_health)
        try:
            return rm.restore(target, step=step, sharding_fn=sharding_fn)
        finally:
            rm.close()

    def subscribe(self, name: str, fn: Callable[[int], None]) -> None:
        """Flush-done events for one tenant, through the plane — the
        handle serving fleets are expected to use."""
        self.manager(name).subscribe(fn)

    def unsubscribe(self, name: str, fn: Callable[[int], None]) -> None:
        self.manager(name).unsubscribe(fn)

    # ------------------------------------------------------ fleet lifecycle

    def drain(self) -> List[str]:
        """One probe/drain pass over every attached tenant, highest
        priority first — after an outage heals, the most important
        job's parked flushes reach the PFS before anyone else's.
        Returns tenant names in the order they were drained."""
        with self._lock:
            order = sorted(
                self._managers,
                key=lambda n: (-self._records[n].priority, n),
            )
        for n in order:
            self._managers[n].health_check()
        return order

    def health(self) -> Dict[str, Any]:
        """Shared-breaker state plus per-tenant manager health."""
        out: Dict[str, Any] = {
            "pfs_circuit": self.health_state(),
            "admission": {
                "total": self.admission.total,
                "held": self.admission.held(),
                "preemptions": self.admission.preemptions,
            },
            "tenants": {},
        }
        with self._lock:
            items = list(self._managers.items())
        for n, m in items:
            h = m.health()
            out["tenants"][n] = {
                "mode": h.mode,
                "parked_steps": list(h.parked_steps),
                "flush_errors": len(m.flush_errors),
            }
        return out

    def health_state(self) -> str:
        return self.storage_health.state("pfs")

    def close(self, *, timeout: float = 60.0) -> None:
        """Close every attached manager (draining their queues) and
        release their quota leaves.  The registry stays on disk — a
        new plane over the same root recovers every job."""
        with self._lock:
            managers = list(self._managers.items())
            self._managers.clear()
        errs: List[BaseException] = []
        for n, m in managers:
            try:
                m.close(timeout=timeout)
            except BaseException as e:
                errs.append(e)
            if self.limiter is not None:
                self.limiter.unregister(n)
        if errs:
            raise errs[0]
