"""Multi-tenant checkpoint control plane.

Public surface:

* :class:`~repro.control.plane.ControlPlane` — register/attach jobs,
  list/pin steps, per-tenant GC policy, cross-geometry restore, and the
  shared arbitration runtime (bandwidth quotas, admission, breaker).
* :class:`~repro.core.admission.AdmissionController` — the cluster-wide
  pending-flush budget with priority preemption (re-exported; it lives
  in ``core`` so the engine can default to a private instance).
* :class:`~repro.core.storage.FairShareLimiter` /
  :func:`~repro.core.storage.fair_share_rates` — the hierarchical
  token-bucket quota layer (re-exported from ``core.storage``).
"""
from repro.control.plane import ControlPlane, JobRecord
from repro.core.admission import AdmissionController
from repro.core.storage import (
    FairShareLimiter,
    TenantLimiter,
    fair_share_rates,
)

__all__ = [
    "ControlPlane",
    "JobRecord",
    "AdmissionController",
    "FairShareLimiter",
    "TenantLimiter",
    "fair_share_rates",
]
