"""llama4-scout-17b-a16e [moe] — 16 experts top-1 + 1 shared, early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E]."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab_size=202048,
        moe=MoEConfig(
            n_experts=16, top_k=1, n_shared=1,
            expert_d_ff=8192, shared_d_ff=8192,
        ),
        # 40 heads don't shard over 16-way TP -> scores replicate on the
        # head dim; a small q-chunk bounds the live score tensor.
        chunk_q=128,
        rope_theta=5e5, param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab_size=256,
        moe=MoEConfig(n_experts=4, top_k=1, n_shared=1, expert_d_ff=96,
                      shared_d_ff=96),
        param_dtype="float32", compute_dtype="float32",
    )
