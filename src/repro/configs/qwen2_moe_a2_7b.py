"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-moe-a2.7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab_size=151936, qkv_bias=True,
        moe=MoEConfig(
            n_experts=60, top_k=4, n_shared=4,
            expert_d_ff=1408, shared_d_ff=5632,
        ),
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=48, vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=2, expert_d_ff=48,
                      shared_d_ff=96),
        param_dtype="float32", compute_dtype="float32",
    )
