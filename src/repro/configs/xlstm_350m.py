"""xlstm-350m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab_size=50304,
        block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),  # 3:1 m:s ratio
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
    )
