"""qwen2-72b [dense] — GQA 64/8, QKV bias [arXiv:2407.10671]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab_size=152064, qkv_bias=True,
        rope_theta=1e6, param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
    )
