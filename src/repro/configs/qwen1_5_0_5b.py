"""qwen1.5-0.5b [dense] — MHA 16/16, QKV bias [hf:Qwen/Qwen1.5-0.5B]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-0.5b", family="dense",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=2816, vocab_size=151936, qkv_bias=True,
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=4, head_dim=24,
        d_ff=192, vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
    )
