"""llama3-405b [dense] — GQA 128/8, 128k vocab [arXiv:2407.21783]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b", family="dense",
        n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
        d_ff=53248, vocab_size=128256,
        rope_theta=5e5, param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=320, vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
    )
