"""whisper-small [audio] — enc-dec; conv/mel frontend is a STUB
(input_specs feeds precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab_size=51865,
        encdec=True, n_enc_layers=12, enc_seq=1500, tie_embeddings=True,
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, n_enc_layers=2, enc_seq=12,
        param_dtype="float32", compute_dtype="float32",
    )
