"""recurrentgemma-2b [hybrid] — RG-LRU + local attention 1:2
[arXiv:2402.19427]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b", family="hybrid",
        n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
        d_ff=7680, vocab_size=256000,
        block_pattern=("rglru", "rglru", "attn"),
        window=2048, tie_embeddings=True,
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=192, vocab_size=256, window=8,
        param_dtype="float32", compute_dtype="float32",
    )
