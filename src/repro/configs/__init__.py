"""Architecture registry: exact public configs + reduced smoke twins.

Every module exposes ``config()`` (the exact published architecture) and
``smoke_config()`` (same family, tiny dims — one CPU forward/train step
in tests).  ``SHAPES`` defines the assigned input-shape cells; a cell is
*applicable* unless it is a decode cell for an encoder-only arch or the
``long_500k`` cell for a quadratic-attention arch (see
``cell_applicable``).
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig

ARCHS = (
    "xlstm-350m",
    "qwen2-72b",
    "llama3-405b",
    "qwen1.5-0.5b",
    "tinyllama-1.1b",
    "llava-next-mistral-7b",
    "qwen2-moe-a2.7b",
    "llama4-scout-17b-a16e",
    "recurrentgemma-2b",
    "whisper-small",
)


def _modname(arch: str) -> str:
    return "repro.configs." + arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_modname(arch)).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(_modname(arch)).smoke_config()


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "pure full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
