"""tinyllama-1.1b [dense] — llama2-arch small, GQA 32/4 [arXiv:2401.02385]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="dense",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
        d_ff=5632, vocab_size=32000,
        param_dtype="float32", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=256,
        param_dtype="float32", compute_dtype="float32",
    )
