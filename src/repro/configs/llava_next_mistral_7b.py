"""llava-next-mistral-7b [vlm] — mistral backbone; anyres patch frontend is a
STUB (input_specs feeds precomputed patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b", family="vlm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        n_patches=576,  # one 24x24 anyres tile worth of patch embeddings
        rope_theta=1e6, param_dtype="bfloat16", compute_dtype="bfloat16",
    )


def smoke_config() -> ModelConfig:
    return config().replace(
        n_layers=2, d_model=128, n_heads=8, n_kv_heads=2, head_dim=16,
        d_ff=256, vocab_size=256, n_patches=8,
        param_dtype="float32", compute_dtype="float32",
    )
